//! The discrete-event core: two serialized resources (GPU, expert link)
//! replaying gating traces through the real cache/scorer logic at paper
//! scale. See sim/mod.rs for scope.

use crate::cache::{CacheManager, Policy, Pool};
use crate::loader::scorer::{self, Class};
use crate::trace::{SeqTrace, TraceSet};
use crate::util::rng::Rng;
use crate::ExpertKey;

use super::params::{SimHardware, SimModel};

/// How a system handles an expert that is not in GPU memory.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MissMode {
    /// load it over the link (expert-offloading systems)
    Load,
    /// compute it on the CPU (Fiddler)
    CpuCompute,
    /// cheaper of loading the low-precision version or CPU compute
    /// (HOBBIT's CPU-GPU cooperative mode, Fig 13/15)
    Cooperative,
}

/// A simulated serving system (HOBBIT or a baseline of Table 2).
#[derive(Debug, Clone)]
pub struct SimSystem {
    pub name: String,
    pub policy: Policy,
    /// token-level dynamic mixed-precision loading (§3.2)
    pub dynamic: bool,
    pub t1: f64,
    pub t2: f64,
    /// prefetch depth p (0 = none)
    pub prefetch_depth: usize,
    /// top-k prediction accuracy per layer offset (Fig 7b)
    pub pred_acc: [f64; 4],
    /// bits per parameter for the hi / lo precision classes
    pub hi_bits: f64,
    pub lo_bits: f64,
    /// fraction of cache bytes given to the low-precision pool
    pub lo_cache_frac: f64,
    pub miss_mode: MissMode,
    /// dense layer-by-layer offloading (Transformers / DeepSpeed): loads
    /// every expert of a layer on demand, no expert cache
    pub dense_offload: bool,
    /// llama.cpp-style static split: resident layers on GPU, the rest on
    /// CPU (no expert transfers at all)
    pub static_split: bool,
    /// CPU expert-compute speed multiplier relative to the hardware
    /// profile's cpu_expert_time (Fiddler's PyTorch path is ~0.6x of
    /// HOBBIT's llama.cpp path, paper §5.4: 3 ms vs 5 ms)
    pub cpu_factor: f64,
}

impl SimSystem {
    /// HOBBIT (fp16 group: fp16 + int4 replacements).
    pub fn hobbit(w: [f64; 4]) -> Self {
        Self {
            name: "HOBBIT".into(),
            policy: Policy::Multidim { w },
            dynamic: true,
            t1: 0.6,
            t2: 0.9,
            prefetch_depth: 2,
            pred_acc: [0.96, 0.90, 0.88, 0.85],
            hi_bits: 16.0,
            lo_bits: 4.0,
            lo_cache_frac: 0.15,
            miss_mode: MissMode::Load,
            dense_offload: false,
            static_split: false,
            cpu_factor: 1.0,
        }
    }

    /// HOBBIT on the int8-served group (Orin): int8 + int2 replacements.
    pub fn hobbit_int8(w: [f64; 4]) -> Self {
        Self { hi_bits: 8.0, lo_bits: 2.0, ..Self::hobbit(w) }
    }

    /// MoE-Offloading (Eliseev & Mazur): LRU cache + gate-input prefetch,
    /// single precision.
    pub fn moe_offloading(bits: f64) -> Self {
        Self {
            name: "MoE-Offloading".into(),
            policy: Policy::Lru,
            dynamic: false,
            prefetch_depth: 1,
            pred_acc: [0.85, 0.0, 0.0, 0.0],
            hi_bits: bits,
            lo_bits: bits,
            lo_cache_frac: 0.0,
            ..Self::hobbit([0.25; 4])
        }
    }

    /// MoE-Infinity: activation-ratio (LFU-style) cache + request-level
    /// prefetch, single precision.
    pub fn moe_infinity(bits: f64) -> Self {
        Self {
            name: "MoE-Infinity".into(),
            policy: Policy::LfuModel,
            dynamic: false,
            prefetch_depth: 1,
            pred_acc: [0.75, 0.0, 0.0, 0.0],
            hi_bits: bits,
            lo_bits: bits,
            lo_cache_frac: 0.0,
            ..Self::hobbit([0.25; 4])
        }
    }

    /// Transformers / DeepSpeed-Inference: dense layer-by-layer offload.
    pub fn dense(name: &str, bits: f64) -> Self {
        Self {
            name: name.into(),
            dense_offload: true,
            dynamic: false,
            prefetch_depth: 0,
            hi_bits: bits,
            lo_bits: bits,
            lo_cache_frac: 0.0,
            ..Self::hobbit([0.25; 4])
        }
    }

    /// llama.cpp: static GPU/CPU layer split.
    pub fn llama_cpp(bits: f64) -> Self {
        Self {
            name: "Llama.cpp".into(),
            static_split: true,
            dynamic: false,
            prefetch_depth: 0,
            hi_bits: bits,
            lo_bits: bits,
            lo_cache_frac: 0.0,
            ..Self::hobbit([0.25; 4])
        }
    }

    /// Fiddler: CPU computes missing experts instead of loading them.
    pub fn fiddler(bits: f64) -> Self {
        Self {
            name: "Fiddler".into(),
            miss_mode: MissMode::CpuCompute,
            cpu_factor: 0.6,
            dynamic: false,
            prefetch_depth: 0,
            policy: Policy::Lru,
            hi_bits: bits,
            lo_bits: bits,
            lo_cache_frac: 0.0,
            ..Self::hobbit([0.25; 4])
        }
    }

    /// HOBBIT cooperative mode (Fig 15).
    pub fn hobbit_coop(w: [f64; 4]) -> Self {
        Self {
            name: "HOBBIT-coop".into(),
            miss_mode: MissMode::Cooperative,
            ..Self::hobbit(w)
        }
    }
}

/// Serialized-link timeline.
struct Link {
    free_at: f64,
    bw: f64,
    lat: f64,
}

impl Link {
    fn enqueue(&mut self, now: f64, bytes: f64) -> f64 {
        let start = self.free_at.max(now);
        self.free_at = start + self.lat + bytes / self.bw;
        self.free_at
    }
}

#[derive(Debug, Clone, Default)]
pub struct DecodeResult {
    pub tokens: u64,
    pub total_time: f64,
    pub compute_time: f64,
    pub load_wait_time: f64,
    pub bytes_loaded: f64,
    pub miss_penalty: f64,
    pub hits: u64,
    pub misses: u64,
    pub prefetch_issued: u64,
    pub prefetch_used: u64,
    pub skipped: u64,
    pub cpu_computed: u64,
}

impl DecodeResult {
    pub fn tps(&self) -> f64 {
        if self.total_time <= 0.0 {
            0.0
        } else {
            self.tokens as f64 / self.total_time
        }
    }

    pub fn load_fraction(&self) -> f64 {
        if self.total_time <= 0.0 {
            0.0
        } else {
            self.load_wait_time / self.total_time
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct PrefillResult {
    pub latency: f64,
}

/// Simulator state shared by prefill + decode over one system run.
pub struct SimRun<'a> {
    pub sys: &'a SimSystem,
    pub hw: &'a SimHardware,
    pub model: &'a SimModel,
    cache: CacheManager,
    link: Link,
    inflight: std::collections::HashMap<(ExpertKey, PoolKey), f64>,
    /// predictions pinned against eviction (§3.3 "mask all predicted
    /// experts"), released at token end
    pinned: Vec<(ExpertKey, PoolKey)>,
    rng: Rng,
    hi_bytes: f64,
    lo_bytes: f64,
}

type PoolKey = bool; // true = hi

fn pool_of(key: PoolKey) -> Pool {
    if key {
        Pool::Hi
    } else {
        Pool::Lo
    }
}

impl<'a> SimRun<'a> {
    pub fn new(sys: &'a SimSystem, hw: &'a SimHardware, model: &'a SimModel, seed: u64) -> Self {
        let hi_bytes = model.expert_bytes_bits(sys.hi_bits);
        let lo_bytes = model.expert_bytes_bits(sys.lo_bits);
        let (hi_cap, lo_cap) = hw.cache_capacity(hi_bytes, lo_bytes, sys.lo_cache_frac);
        let cache = CacheManager::new(
            model.n_layers,
            model.n_experts,
            hi_cap,
            0,
            if sys.lo_cache_frac > 0.0 { lo_cap } else { 1 },
            0,
            sys.policy.clone(),
            lo_bytes / hi_bytes,
        );
        Self {
            sys,
            hw,
            model,
            cache,
            link: Link { free_at: 0.0, bw: hw.load_bw, lat: hw.load_latency },
            inflight: Default::default(),
            pinned: Vec::new(),
            rng: Rng::new(seed),
            hi_bytes,
            lo_bytes,
        }
    }

    /// CPU expert-FFN time scales linearly with expert size (the hardware
    /// profile's cpu_expert_time is calibrated at Mixtral-8x7B's ~169M
    /// params); sys.cpu_factor models the interface gap (§5.4).
    fn cpu_expert_time(&self) -> f64 {
        const MIXTRAL_EXPERT_PARAMS: f64 = 45e9 * 0.96 / 256.0;
        // the interface gap (Fiddler's PyTorch 3ms vs llama.cpp 5ms) only
        // shows on large experts; below ~120M params both interfaces run
        // at the same speed (§5.4: "the smaller expert size leads to
        // similar CPU computation speeds for both interfaces")
        let factor = if self.model.expert_params >= 1.2e8 { self.sys.cpu_factor } else { 1.0 };
        self.hw.cpu_expert_time * factor * (self.model.expert_params / MIXTRAL_EXPERT_PARAMS)
    }

    fn bytes(&self, hi: bool) -> f64 {
        if hi {
            self.hi_bytes
        } else {
            self.lo_bytes
        }
    }

    /// Simulate decoding every token of `trace`, starting the clock at
    /// `t0` (prefill end).
    pub fn decode(&mut self, trace: &SeqTrace, t0: f64) -> DecodeResult {
        let mut res = DecodeResult::default();
        let mut t = t0;
        self.cache.reset_sequence();
        let k = self.model.top_k;

        if self.sys.static_split {
            // llama.cpp: fixed layer split, no transfers during decode
            let model_bytes =
                self.model.n_layers as f64 * self.model.n_experts as f64 * self.hi_bytes;
            let frac = (self.hw.cache_bytes / model_bytes).min(1.0);
            let gpu_layers = (frac * self.model.n_layers as f64).floor();
            let cpu_layers = self.model.n_layers as f64 - gpu_layers;
            // On memory-starved unified platforms (Orin) the CPU-side
            // layers do not fit RAM either: every token's mmap accesses
            // page-fault and stream the layer's weights from SSD
            // (§5.2: "severe page faults ... performance degradation").
            let layer_bytes = self.model.n_experts as f64 * self.hi_bytes;
            let page_fault = if self.hw.name == "JetsonOrin" {
                layer_bytes / self.hw.load_bw
            } else {
                0.0
            };
            let per_tok = gpu_layers * (self.hw.attn_time + k as f64 * self.hw.expert_time)
                + cpu_layers
                    * (4.0 * self.hw.attn_time
                        + k as f64 * self.hw.cpu_expert_time
                        + page_fault);
            res.tokens = trace.n_tokens as u64;
            res.total_time = per_tok * trace.n_tokens as f64;
            res.compute_time = res.total_time;
            return res;
        }

        for tok in 0..trace.n_tokens {
            let t_start = t;
            for l in 0..trace.n_layers {
                // gate + attention compute
                t += self.hw.attn_time;
                res.compute_time += self.hw.attn_time;

                if self.sys.dense_offload {
                    // load the whole layer's experts, no cache
                    let layer_bytes = self.model.n_experts as f64 * self.hi_bytes;
                    let ready = self.link.enqueue(t, layer_bytes);
                    res.bytes_loaded += layer_bytes;
                    if ready > t {
                        res.load_wait_time += ready - t;
                        t = ready;
                    }
                    let ct = k as f64 * self.hw.expert_time;
                    t += ct;
                    res.compute_time += ct;
                    continue;
                }

                self.commit_arrived(t);

                // --- on-demand experts ------------------------------------
                let ev = trace.event(tok, l);
                let decisions =
                    scorer::decide(&ev.probs, k, self.sys.t1, self.sys.t2, self.sys.dynamic);
                self.cache.records.note_token();
                let mut used = 0usize;
                for d in decisions {
                    if d.class == Class::Skip {
                        res.skipped += 1;
                        continue;
                    }
                    used += 1;
                    let hi = d.class == Class::Hi;
                    let key = ExpertKey::new(l, d.expert);
                    t = self.ensure_resident(key, hi, t, l, &mut res);
                    self.cache.note_use(key, pool_of(hi));
                }
                // prefetches are issued once this layer's on-demand loads
                // are queued (the loader's on-demand lane has priority);
                // their transfers overlap this layer's expert compute
                if self.sys.prefetch_depth > 0 {
                    self.issue_prefetches(trace, tok, l, t, &mut res);
                }
                let ct = used as f64 * self.hw.expert_time;
                t += ct;
                res.compute_time += ct;
            }
            res.tokens += 1;
            self.release_pins();
            let _ = t_start;
        }
        res.total_time = t - t0;
        res.miss_penalty = self.cache.stats.miss_penalty;
        res.hits = self.cache.stats.hits_hi + self.cache.stats.hits_lo;
        res.misses = self.cache.stats.misses_hi + self.cache.stats.misses_lo;
        res
    }

    /// Batched decode at sim scale: the rows of `traces` decode in
    /// lockstep, and per (step, layer) the scheduler computes the union of
    /// routed (expert, precision) pairs across the batch and fetches each
    /// unique one **once** — the merged acquire. Attention is charged per
    /// row (as in the real engine, where each sequence owns its KV cache);
    /// the expert compute covers unique experts only, so the link traffic
    /// and the expert FLOPs are shared. Rows whose trace is exhausted drop
    /// out of the lockstep. Models the offloading systems only (`MissMode`
    /// paths); dense-offload/static-split baselines have no per-expert
    /// fetches to merge.
    pub fn decode_batch(&mut self, traces: &[&SeqTrace], t0: f64) -> DecodeResult {
        debug_assert!(
            !self.sys.dense_offload && !self.sys.static_split,
            "batched decode models per-expert offloading systems"
        );
        let mut res = DecodeResult::default();
        let mut t = t0;
        self.cache.reset_sequence();
        let k = self.model.top_k;
        let Some(max_tokens) = traces.iter().map(|tr| tr.n_tokens).max() else {
            return res;
        };
        let n_layers = traces[0].n_layers;
        for tok in 0..max_tokens {
            let alive: Vec<&SeqTrace> =
                traces.iter().copied().filter(|tr| tok < tr.n_tokens).collect();
            if alive.is_empty() {
                break;
            }
            for l in 0..n_layers {
                // attention stays per-row even in a batched step (each
                // sequence owns its KV cache/position — see engine/exec.rs),
                // so the batch shares expert FLOPs and loads, not attention
                let at = alive.len() as f64 * self.hw.attn_time;
                t += at;
                res.compute_time += at;
                self.commit_arrived(t);
                self.cache.records.note_token();

                // union of routed experts across the batch (the merged
                // acquire): dups within the step cost no extra bytes
                let mut union: std::collections::BTreeMap<(u32, bool), u64> =
                    std::collections::BTreeMap::new();
                for tr in &alive {
                    let ev = tr.event(tok, l);
                    let decisions = scorer::decide(
                        &ev.probs,
                        k,
                        self.sys.t1,
                        self.sys.t2,
                        self.sys.dynamic,
                    );
                    for d in decisions {
                        if d.class == Class::Skip {
                            res.skipped += 1;
                            continue;
                        }
                        *union.entry((d.expert, d.class == Class::Hi)).or_insert(0) += 1;
                    }
                }
                let mut used = 0usize;
                for (&(expert, hi), &dups) in union.iter() {
                    used += 1;
                    let key = ExpertKey::new(l, expert);
                    t = self.ensure_resident(key, hi, t, l, &mut res);
                    for _ in 0..dups {
                        self.cache.note_use(key, pool_of(hi));
                    }
                }
                if self.sys.prefetch_depth > 0 {
                    // one planner per step (the batched gate stack predicts
                    // from the first row's trace)
                    self.issue_prefetches(alive[0], tok, l, t, &mut res);
                }
                // unique experts only: the in-batch duplicates share the
                // launch (the FLOP-sharing half of batching)
                let ct = used as f64 * self.hw.expert_time;
                t += ct;
                res.compute_time += ct;
            }
            res.tokens += alive.len() as u64;
            self.release_pins();
        }
        res.total_time = t - t0;
        res.miss_penalty = self.cache.stats.miss_penalty;
        res.hits = self.cache.stats.hits_hi + self.cache.stats.hits_lo;
        res.misses = self.cache.stats.misses_hi + self.cache.stats.misses_lo;
        res
    }

    /// Make `key` usable at time `t`; returns the possibly-advanced time.
    fn ensure_resident(
        &mut self,
        key: ExpertKey,
        hi: bool,
        mut t: f64,
        cur_layer: u32,
        res: &mut DecodeResult,
    ) -> f64 {
        let pool = pool_of(hi);
        let hit = self.cache.access(key, pool);
        if hit {
            return t;
        }
        // free upgrade: a hi copy satisfies a lo request
        if !hi && self.cache.hi.contains_ready(key) {
            let ratio = self.cache.penalty_ratio();
            self.cache.stats.misses_lo -= 1;
            self.cache.stats.miss_penalty -= ratio;
            self.cache.stats.hits_lo += 1;
            return t;
        }
        // already in flight (prefetched)?
        if let Some(&ready) = self.inflight.get(&(key, hi)) {
            // cooperative mode: if the in-flight transfer lands later than
            // the CPU could compute the expert, use the CPU and let the
            // transfer land in cache for future tokens (§4 Fig 13)
            if self.sys.miss_mode == MissMode::Cooperative {
                let cpu_one =
                    self.cpu_expert_time() * if hi { 1.0 } else { 0.5 };
                let cpu_t = (cpu_one - self.hw.expert_time).max(0.0);
                if ready - t > cpu_t {
                    t += cpu_t;
                    res.cpu_computed += 1;
                    return t;
                }
            }
            if ready > t {
                res.load_wait_time += ready - t;
                t = ready;
            }
            self.inflight.remove(&(key, hi));
            self.cache.commit(key, pool);
            res.prefetch_used += 1;
            return t;
        }
        match self.sys.miss_mode {
            MissMode::CpuCompute => {
                // Fiddler: CPU computes it, GPU idles meanwhile
                t += (self.cpu_expert_time() - self.hw.expert_time).max(0.0);
                res.cpu_computed += 1;
                t
            }
            MissMode::Cooperative => {
                let load_t =
                    self.link.lat + self.bytes(hi) / self.link.bw + (self.link.free_at - t).max(0.0);
                // low-precision experts compute ~2x faster on the CPU
                // (int4 ggml kernels), part of the Fig 15/16 coop gains
                let cpu_one = self.cpu_expert_time() * if hi { 1.0 } else { 0.5 };
                let cpu_t = (cpu_one - self.hw.expert_time).max(0.0);
                if cpu_t <= load_t {
                    t += cpu_t;
                    res.cpu_computed += 1;
                    t
                } else {
                    self.load_now(key, hi, t, cur_layer, res)
                }
            }
            MissMode::Load => self.load_now(key, hi, t, cur_layer, res),
        }
    }

    fn load_now(
        &mut self,
        key: ExpertKey,
        hi: bool,
        mut t: f64,
        cur_layer: u32,
        res: &mut DecodeResult,
    ) -> f64 {
        let pool = pool_of(hi);
        if self.cache.reserve(key, pool, cur_layer).is_some() {
            let bytes = self.bytes(hi);
            let ready = self.link.enqueue(t, bytes);
            res.bytes_loaded += bytes;
            if ready > t {
                res.load_wait_time += ready - t;
                t = ready;
            }
            self.cache.commit(key, pool);
        } else {
            // no evictable slot: stream through without caching
            let bytes = self.bytes(hi);
            let ready = self.link.enqueue(t, bytes);
            res.bytes_loaded += bytes;
            if ready > t {
                res.load_wait_time += ready - t;
                t = ready;
            }
        }
        t
    }

    /// Commit every in-flight transfer that has landed by time `t` —
    /// including mispredicted prefetches (they occupy real cache slots,
    /// the pollution the paper's Fig 9 penalty is made of).
    fn commit_arrived(&mut self, t: f64) {
        let arrived: Vec<(ExpertKey, PoolKey)> = self
            .inflight
            .iter()
            .filter(|(_, &ready)| ready <= t)
            .map(|(k, _)| *k)
            .collect();
        for (key, hi) in arrived {
            self.inflight.remove(&(key, hi));
            self.cache.commit(key, pool_of(hi));
        }
    }

    fn pin(&mut self, key: ExpertKey, hi: PoolKey) {
        let _present = match pool_of(hi) {
            Pool::Hi => self.cache.hi.pin(key),
            Pool::Lo => self.cache.lo.pin(key),
        };
        self.pinned.push((key, hi));
    }

    fn release_pins(&mut self) {
        for (key, hi) in self.pinned.drain(..) {
            let had_pin = match pool_of(hi) {
                Pool::Hi => self.cache.hi.unpin(key),
                Pool::Lo => self.cache.lo.unpin(key),
            };
            debug_assert!(had_pin, "sim unpin without matching pin for {key:?}");
        }
    }

    fn issue_prefetches(
        &mut self,
        trace: &SeqTrace,
        tok: u32,
        l: u32,
        t: f64,
        res: &mut DecodeResult,
    ) {
        for j in 1..=self.sys.prefetch_depth.min(4) {
            let target = l + j as u32;
            if target >= trace.n_layers {
                break;
            }
            let acc = self.sys.pred_acc[j - 1];
            let actual = trace.event(tok, target);
            let decisions = scorer::decide(
                &actual.probs,
                self.model.top_k,
                self.sys.t1,
                self.sys.t2,
                self.sys.dynamic,
            );
            let mut all_covered = true;
            for d in decisions {
                // prediction error: with prob (1-acc) a wrong expert is
                // prefetched instead (its transfer still occupies the link
                // — the Fig 9 penalty)
                let expert = if self.rng.f64() < acc {
                    d.expert
                } else {
                    let mut e = self.rng.below(self.model.n_experts as usize) as u32;
                    if e == d.expert {
                        e = (e + 1) % self.model.n_experts;
                    }
                    e
                };
                let hi = !self.sys.dynamic || d.class == Class::Hi;
                let key = ExpertKey::new(target, expert);
                let pool = pool_of(hi);
                if self.cache.contains(key, pool)
                    || self.inflight.contains_key(&(key, hi))
                    || (!hi && self.cache.hi.contains_ready(key))
                {
                    // mask the covered prediction against eviction (§3.3)
                    self.pin(key, hi);
                    continue;
                }
                all_covered = false;
                if d.class == Class::Skip && self.sys.dynamic {
                    continue;
                }
                if self.cache.reserve(key, pool, l).is_some() {
                    let bytes = self.bytes(hi);
                    let ready = self.link.enqueue(t, bytes);
                    res.bytes_loaded += bytes;
                    res.prefetch_issued += 1;
                    self.inflight.insert((key, hi), ready);
                    self.pin(key, hi);
                }
            }
            // adaptive depth (Fig 8): stop at the first uncovered layer
            if !all_covered {
                break;
            }
        }
    }

    /// Simulate a prefill of `s` tokens. Prefill activates (nearly) all
    /// experts per layer (§5.5.2: "the prefill stage utilizes all experts
    /// of each layer, resulting in 100% prediction accuracy"), so systems
    /// with prefetch overlap next-layer loads with current-layer compute.
    pub fn prefill(&mut self, s: usize) -> PrefillResult {
        let l = self.model.n_layers as f64;
        let e = self.model.n_experts as f64;
        let compute_per_layer = s as f64 * self.hw.prefill_token_time;

        if self.sys.static_split {
            let model_bytes = l * e * self.hi_bytes;
            let frac = (self.hw.cache_bytes / model_bytes).min(1.0);
            let gpu_layers = (frac * l).floor();
            // CPU layers compute ~6x slower; memory-starved platforms also
            // stream each CPU layer's weights from SSD once per prefill
            let page_fault = if self.hw.name == "JetsonOrin" {
                e * self.hi_bytes / self.hw.load_bw
            } else {
                0.0
            };
            let lat = gpu_layers * compute_per_layer
                + (l - gpu_layers) * (compute_per_layer * 6.0 + page_fault);
            return PrefillResult { latency: lat };
        }
        if self.sys.miss_mode == MissMode::CpuCompute {
            // Fiddler: every expert's token batch runs on CPU; cost scales
            // with expert count (the paper's Phi-MoE prefill blow-up)
            let lat = l * e * self.cpu_expert_time() * (s as f64 / 16.0).max(1.0);
            return PrefillResult { latency: lat };
        }

        // fraction of each layer missing from cache (cold start handled by
        // whatever is resident from previous requests)
        let mut t = 0.0f64;
        let mut layer_ready = vec![0.0f64; self.model.n_layers as usize];
        // bytes to load per layer
        let (hi_frac, lo_frac, skip_frac) = if self.sys.dynamic {
            (0.67, 0.30, 0.03) // Fig 5b threshold split
        } else {
            (1.0, 0.0, 0.0)
        };
        for li in 0..self.model.n_layers as usize {
            let mut missing_hi = 0.0;
            let mut missing_lo = 0.0;
            for ei in 0..self.model.n_experts {
                let key = ExpertKey::new(li as u32, ei);
                if !self.cache.hi.contains_ready(key) {
                    missing_hi += hi_frac;
                    missing_lo += lo_frac;
                    if let Some(_r) = self.cache.reserve(key, Pool::Hi, li as u32) {
                        self.cache.commit(key, Pool::Hi);
                    }
                }
                let _ = skip_frac;
            }
            let bytes = missing_hi * self.hi_bytes + missing_lo * self.lo_bytes;
            let issue_at = if self.sys.prefetch_depth > 0 { t } else { f64::MAX };
            let ready = if bytes > 0.0 {
                if self.sys.prefetch_depth > 0 {
                    self.link.enqueue(issue_at.min(t), bytes)
                } else {
                    // on-demand: loads start when the layer starts
                    f64::NAN // placeholder, handled below
                }
            } else {
                0.0
            };
            layer_ready[li] = ready;
            if self.sys.prefetch_depth > 0 {
                // overlapped: compute waits for this layer's loads
                t = t.max(ready) + compute_per_layer;
            } else {
                let ready = if bytes > 0.0 { self.link.enqueue(t, bytes) } else { t };
                t = t.max(ready) + compute_per_layer;
            }
        }
        PrefillResult { latency: t }
    }
}

/// Convenience: run `sys` over every sequence of `traces` (prefill of
/// `prompt_len` + full decode), averaging.
pub fn simulate_decode(
    sys: &SimSystem,
    hw: &SimHardware,
    model: &SimModel,
    traces: &TraceSet,
    prompt_len: usize,
    seed: u64,
) -> (PrefillResult, DecodeResult) {
    let mut run = SimRun::new(sys, hw, model, seed);
    let mut pre = PrefillResult::default();
    let mut dec = DecodeResult::default();
    for trace in &traces.seqs {
        let p = run.prefill(prompt_len);
        let d = run.decode(trace, 0.0);
        pre.latency += p.latency;
        dec.tokens += d.tokens;
        dec.total_time += d.total_time;
        dec.compute_time += d.compute_time;
        dec.load_wait_time += d.load_wait_time;
        dec.bytes_loaded += d.bytes_loaded;
        dec.miss_penalty += d.miss_penalty;
        dec.hits += d.hits;
        dec.misses += d.misses;
        dec.prefetch_issued += d.prefetch_issued;
        dec.prefetch_used += d.prefetch_used;
        dec.skipped += d.skipped;
        dec.cpu_computed += d.cpu_computed;
    }
    pre.latency /= traces.seqs.len().max(1) as f64;
    (pre, dec)
}

/// Batched-serving counterpart of [`simulate_decode`]: prefill each
/// sequence, then decode all of them as ONE lockstep batch with merged
/// per-layer expert fetches.
pub fn simulate_decode_batch(
    sys: &SimSystem,
    hw: &SimHardware,
    model: &SimModel,
    traces: &TraceSet,
    prompt_len: usize,
    seed: u64,
) -> (PrefillResult, DecodeResult) {
    let mut run = SimRun::new(sys, hw, model, seed);
    let mut pre = PrefillResult::default();
    for _ in &traces.seqs {
        pre.latency += run.prefill(prompt_len).latency;
    }
    pre.latency /= traces.seqs.len().max(1) as f64;
    let rows: Vec<&SeqTrace> = traces.seqs.iter().collect();
    let dec = run.decode_batch(&rows, 0.0);
    (pre, dec)
}

/// Launch accounting of grouped vs per-row batched decode at sim scale.
/// `decode` carries the timing/traffic of the merged-fetch lockstep run
/// ([`SimRun::decode_batch`]); the counters compare how many expert
/// launches (= dequantizations) each execution mode issues for the same
/// routed work.
#[derive(Debug, Clone, Default)]
pub struct GroupedDecodeResult {
    /// (token, layer) steps simulated
    pub steps: u64,
    /// routed (row, expert) pairs total — the per-row work
    pub routed_rows: u64,
    /// legacy per-row execution: one expert launch per routed pair
    pub per_row_launches: u64,
    /// grouped execution: one launch per unique (expert, precision class)
    /// per step — every duplicate row shares its group's single dequant
    pub grouped_launches: u64,
    /// dequantizations avoided by grouping (`per_row - grouped`)
    pub dequant_reuses: u64,
    /// widest per-step unique-(expert, class) count observed
    pub max_unique_per_step: u64,
    /// timing/traffic of the merged-fetch lockstep batch
    pub decode: DecodeResult,
}

/// Grouped-execution counterpart of [`simulate_decode_batch`]: decode all
/// of `traces` as one lockstep batch (same merged per-layer fetches and
/// timing), and additionally count expert launches under both execution
/// modes. Grouped decode sorts each step's routed (row, expert) pairs by
/// expert and launches once per unique (expert, class) group, so its
/// launch count per step is exactly the unique-expert count — the
/// O(unique experts) collapse the real engine's `grouped_launches`
/// counter reports.
pub fn simulate_grouped_decode(
    sys: &SimSystem,
    hw: &SimHardware,
    model: &SimModel,
    traces: &TraceSet,
    prompt_len: usize,
    seed: u64,
) -> GroupedDecodeResult {
    let (_pre, dec) = simulate_decode_batch(sys, hw, model, traces, prompt_len, seed);
    let mut g = GroupedDecodeResult { decode: dec, ..Default::default() };
    let k = model.top_k;
    let Some(max_tokens) = traces.seqs.iter().map(|tr| tr.n_tokens).max() else {
        return g;
    };
    let n_layers = traces.seqs[0].n_layers;
    for tok in 0..max_tokens {
        let alive: Vec<&SeqTrace> =
            traces.seqs.iter().filter(|tr| tok < tr.n_tokens).collect();
        if alive.is_empty() {
            break;
        }
        for l in 0..n_layers {
            // the same routing decisions decode_batch replays: scorer is
            // deterministic over the trace, so the counts line up exactly
            let mut unique: std::collections::BTreeSet<(u32, bool)> =
                std::collections::BTreeSet::new();
            let mut routed = 0u64;
            for tr in &alive {
                let ev = tr.event(tok, l);
                let decisions =
                    scorer::decide(&ev.probs, k, sys.t1, sys.t2, sys.dynamic);
                for d in decisions {
                    if d.class == Class::Skip {
                        continue;
                    }
                    routed += 1;
                    unique.insert((d.expert, d.class == Class::Hi));
                }
            }
            g.steps += 1;
            g.routed_rows += routed;
            g.per_row_launches += routed;
            g.grouped_launches += unique.len() as u64;
            g.max_unique_per_step = g.max_unique_per_step.max(unique.len() as u64);
        }
    }
    g.dequant_reuses = g.per_row_launches - g.grouped_launches;
    g
}

// ---------------------------------------------------------------------
// Chunked-prefill admission (interleaved-prefill model)
// ---------------------------------------------------------------------

/// Greedy `engine::PREFILL_CHUNKS` split of a prompt — THE chunk
/// schedule: delegates to the engine's own
/// [`crate::engine::prefill_chunk_schedule`], so the DES model can never
/// drift from what the blocking prefill and `PrefillCursor` execute.
pub fn chunk_split(prompt_len: usize) -> Vec<usize> {
    crate::engine::prefill_chunk_schedule(prompt_len)
}

/// One decode token's GPU occupancy at sim scale (attention + top-k
/// experts across every layer; the link is not the bottleneck modeled
/// here — the admission model isolates the *scheduling* stall).
pub fn decode_token_time(hw: &SimHardware, model: &SimModel) -> f64 {
    model.n_layers as f64 * (hw.attn_time + model.top_k as f64 * hw.expert_time)
}

/// GPU occupancy of one prefill chunk of width `c`.
pub fn prefill_chunk_time(hw: &SimHardware, model: &SimModel, c: usize) -> f64 {
    model.n_layers as f64 * c as f64 * hw.prefill_token_time
}

/// Inter-token latency of live decode sequences while a late long-prompt
/// admission runs.
#[derive(Debug, Clone, Default)]
pub struct AdmissionResult {
    /// worst inter-token gap any live sequence observed (s)
    pub max_gap: f64,
    /// p50 / p99 inter-token gap across all live-sequence tokens (s)
    pub p50_gap: f64,
    pub p99_gap: f64,
    /// full prefill latency of the admitted prompt (s)
    pub prefill_latency: f64,
    /// chunks the prompt splits into
    pub chunks: usize,
}

/// The interleaved-prefill admission model: `live` sequences decode
/// round-robin on one serialized GPU; at a fixed point a `prompt_len`
/// admission arrives. `chunked = false` models the blocking scheduler
/// (the whole prefill runs before decode resumes — every live sequence
/// eats an O(full prefill) gap); `chunked = true` models the
/// `PrefillCursor` scheduler (one chunk per slice, a decode round between
/// slices — the gap is bounded by ~one chunk + one round). Deterministic;
/// mirrors `benches/bench_serving.rs`'s real-engine scenario at paper
/// scale.
pub fn simulate_admission(
    hw: &SimHardware,
    model: &SimModel,
    live: usize,
    prompt_len: usize,
    decode_tokens_after: usize,
    chunked: bool,
) -> AdmissionResult {
    assert!(live > 0, "admission model needs at least one live sequence");
    let tau_d = decode_token_time(hw, model);
    let chunks = chunk_split(prompt_len);
    let prefill_latency: f64 =
        chunks.iter().map(|&c| prefill_chunk_time(hw, model, c)).sum();

    let mut t = 0.0f64;
    let mut last = vec![0.0f64; live];
    let mut gaps: Vec<f64> = Vec::new();
    let decode_round = |t: &mut f64, last: &mut [f64], gaps: &mut Vec<f64>| {
        for s in 0..live {
            *t += tau_d;
            gaps.push(*t - last[s]);
            last[s] = *t;
        }
    };
    // steady-state rounds before the admission
    for _ in 0..3 {
        decode_round(&mut t, &mut last, &mut gaps);
    }
    if chunked {
        // one chunk per scheduler slice, a full decode round in between
        for &c in &chunks {
            t += prefill_chunk_time(hw, model, c);
            decode_round(&mut t, &mut last, &mut gaps);
        }
    } else {
        // blocking admission: decode resumes only after the whole prefill
        t += prefill_latency;
    }
    for _ in 0..decode_tokens_after.max(1) {
        decode_round(&mut t, &mut last, &mut gaps);
    }
    let summary = crate::util::stats::summarize(&gaps);
    AdmissionResult {
        max_gap: summary.max,
        p50_gap: summary.p50,
        p99_gap: summary.p99,
        prefill_latency,
        chunks: chunks.len(),
    }
}

// ---------------------------------------------------------------------
// Chunked multi-lane transfer pipeline (misprediction-penalty model)
// ---------------------------------------------------------------------

/// Outcome of the misprediction-penalty scenario: an on-demand miss
/// arrives just behind a wrong prefetch whose transfer already started
/// (the §3.3/Fig 9 worst case).
#[derive(Debug, Clone, Default)]
pub struct MispredictResult {
    /// arrival → ready of the on-demand expert (the decode stall)
    pub ondemand_wait: f64,
    /// wall time until the link drains (both transfers complete)
    pub drain: f64,
    /// total bytes moved across the link
    pub bytes_moved: f64,
}

/// Mirror of the loader's chunked transfer pipeline at DES scale (single
/// lane — the worst case; extra lanes only shrink the wait further): a
/// mispredicted prefetch of `prefetch_bytes` starts at t = 0, and the
/// on-demand miss of `ondemand_bytes` arrives at `arrive` (mid-transfer).
///
/// `preemptible = false` models the paper's non-preemptible memcpy: the
/// miss waits out the entire in-flight prefetch. `preemptible = true`
/// models the chunked pipeline: the prefetch yields at the first
/// `chunk_bytes` checkpoint after the arrival (a chunk itself is one
/// non-preemptible DMA call), the on-demand transfer runs, and the
/// prefetch resumes from its kept offset — so the penalty is O(one chunk)
/// instead of O(prefetch bytes), while the drain time and total bytes are
/// identical (the pipeline is work-conserving).
pub fn simulate_misprediction(
    bw: f64,
    prefetch_bytes: f64,
    ondemand_bytes: f64,
    chunk_bytes: f64,
    arrive: f64,
    preemptible: bool,
) -> MispredictResult {
    let p_total = prefetch_bytes / bw;
    let d_total = ondemand_bytes / bw;
    let chunk = (chunk_bytes.max(1.0) / bw).min(p_total.max(1e-12));
    let arrive = arrive.clamp(0.0, p_total);
    let (ondemand_start, resume_left) = if preemptible {
        // the checkpoint at the end of the chunk in flight when the miss
        // arrives (a chunk is one non-preemptible DMA call)
        let boundary = (((arrive / chunk).floor() + 1.0) * chunk).min(p_total);
        (boundary, p_total - boundary)
    } else {
        (p_total, 0.0)
    };
    let ready = ondemand_start + d_total;
    MispredictResult {
        ondemand_wait: ready - arrive,
        drain: ready + resume_left,
        bytes_moved: prefetch_bytes + ondemand_bytes,
    }
}

// ---------------------------------------------------------------------
// Progressive (lo-bits-first) staged fetch model
// ---------------------------------------------------------------------

/// Outcome of the progressive staged-fetch scenario: an on-demand miss
/// streams its lo record first (the expert is *usable* the moment that
/// commits), then the hi record upgrades the slot in place from the
/// background lane.
#[derive(Debug, Clone, Default)]
pub struct ProgressiveFetchResult {
    /// miss → the expert is usable (lo record committed)
    pub time_to_first_usable: f64,
    /// miss → the hi record has upgraded the slot in place
    pub upgrade_done: f64,
    /// total bytes moved across the link
    pub bytes_moved: f64,
}

/// Mirror of the loader's staged lo→hi streaming at DES scale. The miss's
/// transfer runs chunk-by-chunk on the shared link: while `competing` a
/// background prefetch stream holds the other lane, so the on-demand stage
/// gets the weighted fair share `ONDEMAND/(ONDEMAND+PREFETCH)` of `bw` and
/// the upgrade continuation — which runs at prefetch weight — gets half of
/// `bw`. Usability lands at the end of the chunk carrying the lo record's
/// last byte, so time-to-first-usable is bounded by the lo bytes at the
/// fair share plus one chunk (plus the per-transfer DMA latency); the hi
/// bytes cost only background bandwidth after that. `lo_bytes ==
/// hi_bytes` degenerates to the single-stage (hi-only) fetch: the
/// "upgrade" is the fetch itself, so `upgrade_done ==
/// time_to_first_usable` and only `hi_bytes` moves.
pub fn simulate_progressive_fetch(
    bw: f64,
    latency: f64,
    lo_bytes: f64,
    hi_bytes: f64,
    chunk_bytes: f64,
    competing: bool,
) -> ProgressiveFetchResult {
    use crate::memory::{ONDEMAND_WEIGHT, PREFETCH_WEIGHT};
    let od_share = if competing {
        bw * ONDEMAND_WEIGHT / (ONDEMAND_WEIGHT + PREFETCH_WEIGHT)
    } else {
        bw
    };
    let pf_share = if competing { bw * 0.5 } else { bw };
    let chunk = chunk_bytes.max(1.0);
    // chunk-granular: the commit happens at the end of the chunk holding
    // the record's last byte
    let lo_chunks = (lo_bytes / chunk).ceil().max(1.0);
    let ttfu = latency + lo_chunks * chunk / od_share;
    if hi_bytes <= lo_bytes {
        // single-stage fetch (pinned / progressive-off): no continuation
        return ProgressiveFetchResult {
            time_to_first_usable: ttfu,
            upgrade_done: ttfu,
            bytes_moved: lo_bytes,
        };
    }
    // the continuation re-pays the DMA setup and streams the full hi
    // record at background (prefetch) weight
    let hi_chunks = (hi_bytes / chunk).ceil().max(1.0);
    let upgrade_done = ttfu + latency + hi_chunks * chunk / pf_share;
    ProgressiveFetchResult {
        time_to_first_usable: ttfu,
        upgrade_done,
        bytes_moved: lo_bytes + hi_bytes,
    }
}

#[derive(Debug, Clone, Default)]
pub struct RemoteClusterResult {
    /// decode tokens completed across every user
    pub tokens: u64,
    /// wall time until the last user finishes
    pub total_time: f64,
    /// expert fetches served by a peer over the network
    pub remote_fetches: u64,
    /// peer-resident fetches answered from the staged side-cache instead
    pub staged_hits: u64,
    /// bytes crossing node network links
    pub net_bytes: f64,
    /// summed busy time of every node's network link
    pub net_busy: f64,
    /// summed busy time of every node's PCIe link
    pub pcie_busy: f64,
}

impl RemoteClusterResult {
    pub fn tps(&self) -> f64 {
        if self.total_time <= 0.0 {
            0.0
        } else {
            self.tokens as f64 / self.total_time
        }
    }

    /// Mean utilization of the network links (0..1).
    pub fn net_utilization(&self, n_nodes: usize) -> f64 {
        if self.total_time <= 0.0 {
            0.0
        } else {
            self.net_busy / (self.total_time * n_nodes.max(1) as f64)
        }
    }
}

/// N nodes × M users over the remote expert tier, at DES scale.
///
/// Each node's DRAM holds a `1/N` shard of the experts; users are pinned
/// round-robin to nodes and decode `tokens_per_user` tokens of `top_k`
/// expert demands per token. A demanded expert misses HBM with
/// `miss_rate`; a miss is peer-resident with probability `(N-1)/N` (the
/// shard geometry), in which case it crosses the node's *network* link
/// first — unless the cross-tier stager already pulled it
/// (`staged_hit_rate`) — and then the node's *PCIe* link like every other
/// miss. The two links are separate serialized timelines per node, which
/// is exactly the point: network service never consumes PCIe budget, so a
/// slow interconnect shows up as net-link queueing (and a lower tok/s),
/// not as phantom PCIe pressure. Deterministic in `seed`.
#[allow(clippy::too_many_arguments)]
pub fn simulate_remote_cluster(
    n_nodes: usize,
    m_users: usize,
    tokens_per_user: usize,
    expert_bytes: f64,
    miss_rate: f64,
    staged_hit_rate: f64,
    compute_s: f64,
    pcie: (f64, f64),
    net: (f64, f64),
    top_k: usize,
    seed: u64,
) -> RemoteClusterResult {
    let n = n_nodes.max(1);
    let mut net_links: Vec<Link> =
        (0..n).map(|_| Link { free_at: 0.0, bw: net.0.max(1.0), lat: net.1 }).collect();
    let mut pcie_links: Vec<Link> =
        (0..n).map(|_| Link { free_at: 0.0, bw: pcie.0.max(1.0), lat: pcie.1 }).collect();
    let mut rng = Rng::new(seed ^ 0x5eed_c705);
    let mut out = RemoteClusterResult::default();
    let mut user_clock = vec![0.0f64; m_users.max(1)];
    let peer_frac = (n as f64 - 1.0) / n as f64;
    for _t in 0..tokens_per_user {
        for (u, clock) in user_clock.iter_mut().enumerate() {
            let node = u % n;
            let now = *clock;
            let mut ready = now;
            for _k in 0..top_k.max(1) {
                if rng.f64() >= miss_rate {
                    continue;
                }
                // where do the bytes live?
                let mut start = now;
                if rng.f64() < peer_frac {
                    if rng.f64() < staged_hit_rate {
                        // already pulled into local DRAM by the stager:
                        // no network time on the demand path
                        out.staged_hits += 1;
                    } else {
                        let l = &mut net_links[node];
                        out.net_busy += l.lat + expert_bytes / l.bw;
                        start = l.enqueue(now, expert_bytes);
                        out.remote_fetches += 1;
                        out.net_bytes += expert_bytes;
                    }
                }
                // every miss then crosses PCIe into HBM
                let l = &mut pcie_links[node];
                out.pcie_busy += l.lat + expert_bytes / l.bw;
                ready = ready.max(l.enqueue(start, expert_bytes));
            }
            *clock = ready + compute_s;
            out.tokens += 1;
        }
    }
    out.total_time = user_clock.iter().cloned().fold(0.0, f64::max);
    out
}

// ---------------------------------------------------------------------
// Faulty-link model (integrity layer's re-fetch penalty)
// ---------------------------------------------------------------------

/// Outcome of [`simulate_faulty_link`]: the latency cost of integrity
/// healing at DES scale.
#[derive(Debug, Clone, Default)]
pub struct FaultyLinkResult {
    /// expert fetches demanded
    pub fetches: u64,
    /// fetches whose peer bytes failed verification (quarantined)
    pub corrupt: u64,
    /// clean re-fetches from the next tier down (here: disk)
    pub refetches: u64,
    /// wall time until the last fetch verifies, with corruption
    pub total_time: f64,
    /// wall time of the identical fetch sequence with zero corruption
    pub clean_time: f64,
}

impl FaultyLinkResult {
    /// Extra wall time the corruption cost (the heal penalty).
    pub fn heal_penalty(&self) -> f64 {
        (self.total_time - self.clean_time).max(0.0)
    }
}

/// DES twin of the integrity layer's quarantine-and-heal path: `n_fetches`
/// expert records are pulled over a peer network link; each delivery is
/// corrupt with probability `corrupt_rate` (deterministic in `seed`), in
/// which case the bytes are quarantined and the record is re-fetched once
/// from the next tier down — the disk link — which always verifies
/// (matching the real system, where the manifest checksums come FROM
/// disk). Both links are serialized timelines, so the model also captures
/// queueing behind the healing traffic. The invariant this exists to pin:
/// per corruption, healing costs at most one extra tier fetch — never a
/// retry storm.
pub fn simulate_faulty_link(
    n_fetches: usize,
    expert_bytes: f64,
    corrupt_rate: f64,
    peer: (f64, f64),
    disk: (f64, f64),
    seed: u64,
) -> FaultyLinkResult {
    let mut out = FaultyLinkResult::default();
    let mut rng = Rng::new(seed ^ 0xfa17_11e5);
    let mut net = Link { free_at: 0.0, bw: peer.0.max(1.0), lat: peer.1 };
    let mut dsk = Link { free_at: 0.0, bw: disk.0.max(1.0), lat: disk.1 };
    let mut clean_net = Link { free_at: 0.0, bw: peer.0.max(1.0), lat: peer.1 };
    let mut now = 0.0f64;
    let mut clean_now = 0.0f64;
    for _ in 0..n_fetches {
        out.fetches += 1;
        let mut done = net.enqueue(now, expert_bytes);
        if rng.f64() < corrupt_rate {
            // commit-time verification rejects the peer bytes: quarantine,
            // then exactly one clean fetch from the tier below
            out.corrupt += 1;
            out.refetches += 1;
            done = dsk.enqueue(done, expert_bytes);
        }
        now = done;
        clean_now = clean_net.enqueue(clean_now, expert_bytes);
    }
    out.total_time = now;
    out.clean_time = clean_now;
    out
}

// ---------------------------------------------------------------------
// Open-loop overload model (traffic harness + degradation ladder)
// ---------------------------------------------------------------------

/// Outcome of the open-loop overload scenario: a bursty arrival trace
/// offered to one serialized engine behind a bounded admission queue,
/// with or without the precision-first degradation ladder.
#[derive(Debug, Clone, Default)]
pub struct OpenLoopResult {
    /// arrivals the trace offered
    pub offered: usize,
    /// arrivals admitted (offered − rejected)
    pub admitted: usize,
    /// arrivals rejected at the admission bound
    pub rejected: usize,
    /// admitted requests whose TTFT met the SLO
    pub slo_met: usize,
    /// requests the ladder served at the degraded (lo) precision
    pub shed_rounds: u64,
    /// output tokens of SLO-met requests / makespan
    pub goodput_tps: f64,
    /// TTFT tail across admitted requests (s)
    pub ttft_p50: f64,
    pub ttft_p99: f64,
    pub ttft_p999: f64,
    /// first arrival → last completion (s)
    pub makespan: f64,
}

/// The serving overload model at DES scale: the arrival side is the real
/// trace generator (`workload::generate_trace` — bursty nonhomogeneous
/// Poisson, heavy-tailed lengths), the service side is one FIFO engine
/// whose per-token cost depends on the fetch precision: `tau_hi` at full
/// precision, `tau_lo` when the ladder has shed the progressive floor to
/// the lo tier (fewer bytes per expert fetch → faster service). A request
/// arriving with `queue_limit` requests already in the system is rejected;
/// with `ladder` set, a request starting service while the system is at or
/// beyond `precision_frac` of the bound is served at `tau_lo`. TTFT is
/// queue wait + prefill; goodput counts only tokens of requests whose
/// TTFT met `slo_ttft`. Deterministic in `cfg.seed` — this is the
/// closed-form twin of `rust/tests/overload.rs`'s live-engine assertions
/// and the acceptance-criterion demonstration (ladder goodput ≥ 1.5× the
/// no-ladder baseline at 2× sustained overload).
pub fn simulate_open_loop(
    cfg: &crate::workload::WorkloadConfig,
    queue_limit: usize,
    precision_frac: f64,
    ladder: bool,
    tau_hi: f64,
    tau_lo: f64,
    prefill_tok_s: f64,
    slo_ttft: f64,
) -> OpenLoopResult {
    let trace = crate::workload::generate_trace(cfg);
    let limit = queue_limit.max(1);
    let shed_at = ((limit as f64 * precision_frac).ceil() as usize).max(1);
    let mut out = OpenLoopResult { offered: trace.len(), ..Default::default() };
    // FIFO single server: `in_system` holds completion times of admitted
    // requests that may still be queued or running at the next arrival
    let mut in_system: std::collections::VecDeque<f64> = std::collections::VecDeque::new();
    let mut free_at = 0.0f64;
    let mut ttfts: Vec<f64> = Vec::new();
    let mut good_tokens = 0u64;
    let mut last_done = 0.0f64;
    for ev in &trace.events {
        while in_system.front().is_some_and(|&done| done <= ev.at_s) {
            in_system.pop_front();
        }
        if in_system.len() >= limit {
            out.rejected += 1;
            continue;
        }
        out.admitted += 1;
        let tau = if ladder && in_system.len() >= shed_at {
            out.shed_rounds += 1;
            tau_lo
        } else {
            tau_hi
        };
        let start = free_at.max(ev.at_s);
        let prefill = ev.prompt_tokens as f64 * prefill_tok_s;
        let done = start + prefill + ev.max_new_tokens as f64 * tau;
        let ttft = start + prefill - ev.at_s;
        ttfts.push(ttft);
        if ttft <= slo_ttft {
            out.slo_met += 1;
            good_tokens += ev.max_new_tokens as u64;
        }
        free_at = done;
        last_done = last_done.max(done);
        in_system.push_back(done);
    }
    if !ttfts.is_empty() {
        ttfts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = |f: f64| {
            let rank = ((ttfts.len() as f64 * f).ceil() as usize).max(1) - 1;
            ttfts[rank.min(ttfts.len() - 1)]
        };
        out.ttft_p50 = q(0.50);
        out.ttft_p99 = q(0.99);
        out.ttft_p999 = q(0.999);
    }
    let first = trace.events.first().map(|e| e.at_s).unwrap_or(0.0);
    out.makespan = (last_done - first).max(0.0);
    if out.makespan > 0.0 {
        out.goodput_tps = good_tokens as f64 / out.makespan;
    }
    out
}

/// Prefill-only helper.
pub fn simulate_prefill(
    sys: &SimSystem,
    hw: &SimHardware,
    model: &SimModel,
    s: usize,
    seed: u64,
) -> PrefillResult {
    SimRun::new(sys, hw, model, seed).prefill(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{generate, TraceGenConfig};

    fn setup() -> (SimHardware, SimModel, TraceSet) {
        let hw = SimHardware::rtx4090();
        let model = SimModel::mixtral_8x7b();
        let traces = generate(&TraceGenConfig::mixtral_like(), 2, 24);
        (hw, model, traces)
    }

    #[test]
    fn faulty_link_heal_costs_at_most_one_tier_fetch() {
        let bytes = 4.0e6;
        let peer = (1.0e9, 0.5e-3);
        let disk = (0.5e9, 1.0e-3);
        let r = simulate_faulty_link(200, bytes, 0.2, peer, disk, 7);
        assert!(r.corrupt > 0, "0.2 corruption rate over 200 fetches must fire");
        assert_eq!(r.refetches, r.corrupt, "every quarantine heals exactly once");
        // the invariant: per corruption, healing costs at most one fetch
        // from the next tier down — never a retry storm
        let disk_fetch = disk.1 + bytes / disk.0;
        assert!(
            r.heal_penalty() <= r.corrupt as f64 * disk_fetch + 1e-9,
            "penalty {} > {} corruptions x one disk fetch {}",
            r.heal_penalty(),
            r.corrupt,
            disk_fetch,
        );
        assert!(r.heal_penalty() > 0.0, "corruption is never free");
        // a fault-free run costs exactly the clean timeline
        let r0 = simulate_faulty_link(200, bytes, 0.0, peer, disk, 7);
        assert_eq!(r0.corrupt, 0);
        assert_eq!(r0.total_time, r0.clean_time);
    }

    #[test]
    fn hobbit_beats_single_precision_baselines() {
        let (hw, model, traces) = setup();
        let hb = simulate_decode(&SimSystem::hobbit([0.65, 0.05, 0.10, 0.20]), &hw, &model, &traces, 16, 1).1;
        let mo = simulate_decode(&SimSystem::moe_offloading(16.0), &hw, &model, &traces, 16, 1).1;
        let mi = simulate_decode(&SimSystem::moe_infinity(16.0), &hw, &model, &traces, 16, 1).1;
        assert!(hb.tps() > mo.tps(), "HB {} !> MO {}", hb.tps(), mo.tps());
        assert!(hb.tps() > mi.tps(), "HB {} !> MI {}", hb.tps(), mi.tps());
    }

    #[test]
    fn dense_offload_is_slowest() {
        let (hw, model, traces) = setup();
        let hb = simulate_decode(&SimSystem::hobbit([0.65, 0.05, 0.10, 0.20]), &hw, &model, &traces, 16, 1).1;
        let tf = simulate_decode(&SimSystem::dense("Transformers", 16.0), &hw, &model, &traces, 16, 1).1;
        assert!(hb.tps() > 2.0 * tf.tps(), "HB {} vs dense {}", hb.tps(), tf.tps());
    }

    #[test]
    fn loading_dominates_decode_time() {
        // Fig 3a at sim scale
        let (hw, model, traces) = setup();
        let mo = simulate_decode(&SimSystem::moe_offloading(16.0), &hw, &model, &traces, 16, 1).1;
        assert!(mo.load_fraction() > 0.6, "load fraction {}", mo.load_fraction());
    }

    #[test]
    fn dynamic_loading_reduces_bytes() {
        let (hw, model, traces) = setup();
        let hb = simulate_decode(&SimSystem::hobbit([0.65, 0.05, 0.10, 0.20]), &hw, &model, &traces, 16, 1).1;
        let mut nodyn = SimSystem::hobbit([0.65, 0.05, 0.10, 0.20]);
        nodyn.dynamic = false;
        let nd = simulate_decode(&nodyn, &hw, &model, &traces, 16, 2).1;
        assert!(hb.bytes_loaded < nd.bytes_loaded);
        assert!(hb.tps() > nd.tps(), "dynamic {} !> static {}", hb.tps(), nd.tps());
    }

    #[test]
    fn batched_decode_merges_loads_and_shares_flops() {
        let (hw, model, traces) = setup();
        let sys = SimSystem::hobbit([0.65, 0.05, 0.10, 0.20]);
        let seq = simulate_decode(&sys, &hw, &model, &traces, 16, 1).1;
        let bat = simulate_decode_batch(&sys, &hw, &model, &traces, 16, 1).1;
        assert_eq!(bat.tokens, seq.tokens, "lockstep batch must decode every token");
        // the routing union is smaller than the routing sum: merged
        // fetches move fewer bytes than per-sequence decode
        assert!(
            bat.bytes_loaded < seq.bytes_loaded,
            "batched {} !< sequential {}",
            bat.bytes_loaded,
            seq.bytes_loaded
        );
        // union-only expert compute + merged loads on a load-dominated
        // link: faster per token even with attention charged per row
        assert!(bat.tps() > seq.tps(), "batched {} !> sequential {}", bat.tps(), seq.tps());
    }

    #[test]
    fn grouped_decode_launches_collapse_to_unique_experts() {
        // the perf claim at --max-batch 16, in its deterministic DES form:
        // 16 rows x top-2 routing over 8 experts issues ~32 per-row
        // launches per step, but grouped execution launches once per
        // unique (expert, class) — bounded by the expert count, not the
        // batch width
        let hw = SimHardware::rtx4090();
        let model = SimModel::mixtral_8x7b();
        let traces = generate(&TraceGenConfig::mixtral_like(), 16, 24);
        let sys = SimSystem::hobbit([0.65, 0.05, 0.10, 0.20]);
        let g = simulate_grouped_decode(&sys, &hw, &model, &traces, 16, 1);
        assert!(g.steps > 0);
        // launches/step is pinned by unique-experts/step: never more than
        // one launch per (expert, class) pair, however wide the batch
        assert!(
            g.max_unique_per_step <= 2 * model.n_experts as u64,
            "unique groups per step {} exceed the expert-pair ceiling {}",
            g.max_unique_per_step,
            2 * model.n_experts
        );
        assert!(
            g.grouped_launches <= g.steps * 2 * model.n_experts as u64,
            "grouped launches {} exceed steps x expert pairs",
            g.grouped_launches
        );
        // grouping never launches more than per-row execution, and at
        // batch 16 the collapse is real: duplicates share dequants
        assert!(g.grouped_launches <= g.per_row_launches);
        assert!(
            g.dequant_reuses > 0,
            "16 rows routing into 8 experts must share dequants"
        );
        assert_eq!(
            g.dequant_reuses,
            g.per_row_launches - g.grouped_launches,
            "reuse accounting"
        );
        // at this width the sharing is substantial — the FLOP-sharing win
        assert!(
            2 * g.grouped_launches <= g.per_row_launches,
            "grouped {} !<= half of per-row {}",
            g.grouped_launches,
            g.per_row_launches
        );
        // and the timing side still decodes every token of every row
        let want: u64 = traces.seqs.iter().map(|t| t.n_tokens as u64).sum();
        assert_eq!(g.decode.tokens, want);
    }

    #[test]
    fn batched_decode_handles_ragged_lengths() {
        let hw = SimHardware::rtx4090();
        let model = SimModel::mixtral_8x7b();
        let a = generate(&TraceGenConfig::mixtral_like(), 1, 8);
        let b = generate(&TraceGenConfig::mixtral_like(), 1, 24);
        let sys = SimSystem::hobbit([0.65, 0.05, 0.10, 0.20]);
        let mut run = SimRun::new(&sys, &hw, &model, 7);
        let rows: Vec<&SeqTrace> = vec![&a.seqs[0], &b.seqs[0]];
        let d = run.decode_batch(&rows, 0.0);
        // short row drops out of the lockstep; long row finishes alone
        assert_eq!(d.tokens, 8 + 24);
    }

    #[test]
    fn chunk_split_follows_prefill_chunks() {
        assert_eq!(chunk_split(1), vec![1]);
        assert_eq!(chunk_split(16), vec![16]);
        assert_eq!(chunk_split(129), vec![128, 1]);
        let mut want = vec![128, 128, 16, 16];
        want.extend_from_slice(&[1; 12]);
        assert_eq!(chunk_split(300), want);
        assert_eq!(chunk_split(300).iter().sum::<usize>(), 300);
    }

    #[test]
    fn chunked_admission_bounds_decode_stall_to_one_chunk() {
        let hw = SimHardware::rtx4090();
        let model = SimModel::mixtral_8x7b();
        let live = 3usize;
        let prompt = 1024usize; // 8 chunks of 128
        let blocking = simulate_admission(&hw, &model, live, prompt, 4, false);
        let chunked = simulate_admission(&hw, &model, live, prompt, 4, true);
        assert_eq!(blocking.chunks, 8);
        assert!(
            (blocking.prefill_latency - chunked.prefill_latency).abs() < 1e-12,
            "chunking must not change total prefill work"
        );
        // blocking: some live sequence's gap contains the WHOLE prefill
        assert!(
            blocking.max_gap >= blocking.prefill_latency,
            "blocking max gap {} < prefill {}",
            blocking.max_gap,
            blocking.prefill_latency
        );
        // chunked: the stall bound drops from O(full prefill) to O(one
        // chunk): worst gap <= one 128-chunk + one full decode round
        let bound = prefill_chunk_time(&hw, &model, 128)
            + live as f64 * decode_token_time(&hw, &model)
            + 1e-12;
        assert!(
            chunked.max_gap <= bound,
            "chunked max gap {} exceeds one-chunk bound {}",
            chunked.max_gap,
            bound
        );
        assert!(chunked.p99_gap <= bound);
        // and it is far below the blocking stall on a long prompt
        assert!(
            blocking.max_gap > 4.0 * chunked.max_gap,
            "blocking {} vs chunked {}",
            blocking.max_gap,
            chunked.max_gap
        );
    }

    #[test]
    fn chunked_preemption_bounds_misprediction_penalty() {
        let bw = 1.5e9; // the rtx4090-real link
        let expert = 1_572_864.0; // one f32 tiny expert
        let chunk = 262_144.0; // the default --io-chunk-bytes
        let arrive = 0.5 * chunk / bw; // miss lands mid first chunk
        let mono = simulate_misprediction(bw, expert, expert, chunk, arrive, false);
        let pipe = simulate_misprediction(bw, expert, expert, chunk, arrive, true);
        // work conservation: same bytes, same drain time either way —
        // chunking changes WHEN bytes arrive, never what (or how much)
        assert_eq!(mono.bytes_moved, pipe.bytes_moved);
        assert!((mono.drain - pipe.drain).abs() < 1e-12);
        let d = expert / bw;
        let chunk_t = chunk / bw;
        // non-preemptible: the miss eats ~the whole in-flight prefetch
        assert!(mono.ondemand_wait >= d + (expert - chunk) / bw);
        // chunked: at most one chunk + the on-demand transfer itself
        assert!(
            pipe.ondemand_wait <= chunk_t + d + 1e-12,
            "pipelined wait {} exceeds one-chunk bound {}",
            pipe.ondemand_wait,
            chunk_t + d
        );
        // the stall behind the prefetch (wait minus the miss's own
        // transfer) drops >= 4x at the default chunk size (6 chunks per
        // expert -> ~11x here)
        let stall_mono = mono.ondemand_wait - d;
        let stall_pipe = pipe.ondemand_wait - d;
        assert!(stall_pipe > 0.0);
        assert!(
            stall_mono >= 4.0 * stall_pipe,
            "stall {} vs {} (expected >= 4x drop)",
            stall_mono,
            stall_pipe
        );
    }

    #[test]
    fn misprediction_model_degenerate_cases_stay_finite() {
        // chunk larger than the record: preemption can't help (one DMA)
        let r = simulate_misprediction(1e9, 1000.0, 1000.0, 1e9, 0.0, true);
        assert!((r.ondemand_wait - 2e-6).abs() < 1e-12);
        // arrival after the prefetch finished: no queueing either way
        let late = simulate_misprediction(1e9, 1000.0, 500.0, 100.0, 1.0, false);
        assert!((late.ondemand_wait - 5e-7).abs() < 1e-12);
    }

    #[test]
    fn progressive_fetch_bounds_time_to_first_usable_by_the_lo_record() {
        use crate::memory::{ONDEMAND_WEIGHT, PREFETCH_WEIGHT};
        let bw = 1.5e9; // the rtx4090-real link
        let hi = 1_572_864.0; // one f32 tiny expert
        let lo = hi / 8.0; // its q4 record
        let chunk = 262_144.0; // the default --io-chunk-bytes
        let lat = 30e-6;
        let r = simulate_progressive_fetch(bw, lat, lo, hi, chunk, true);
        // usability lands within the lo record at fair-share bandwidth
        // plus one chunk (the commit waits for the chunk boundary)
        let share = bw * ONDEMAND_WEIGHT / (ONDEMAND_WEIGHT + PREFETCH_WEIGHT);
        let bound = lat + lo / share + chunk / share + 1e-12;
        assert!(
            r.time_to_first_usable <= bound,
            "ttfu {} exceeds lo-record bound {}",
            r.time_to_first_usable,
            bound
        );
        // the upgrade finishes strictly later and moves both records
        assert!(r.upgrade_done > r.time_to_first_usable);
        assert_eq!(r.bytes_moved, lo + hi);
    }

    #[test]
    fn progressive_fetch_halves_miss_stall_vs_hi_only() {
        // the acceptance bound: at the Q4/F32 default byte ratio the
        // on-demand miss becomes usable >= 2x sooner than a hi-only fetch
        let bw = 1.5e9;
        let hi = 1_572_864.0;
        let lo = hi / 8.0;
        let chunk = 262_144.0;
        let lat = 30e-6;
        let prog = simulate_progressive_fetch(bw, lat, lo, hi, chunk, true);
        let hi_only = simulate_progressive_fetch(bw, lat, hi, hi, chunk, true);
        assert_eq!(hi_only.time_to_first_usable, hi_only.upgrade_done);
        assert_eq!(hi_only.bytes_moved, hi);
        assert!(
            hi_only.time_to_first_usable >= 2.0 * prog.time_to_first_usable,
            "hi-only ttfu {} vs progressive {} (expected >= 2x reduction)",
            hi_only.time_to_first_usable,
            prog.time_to_first_usable
        );
    }

    #[test]
    fn remote_cluster_network_is_a_second_link_class() {
        // one f32 tiny expert over a PCIe-class link and a slower network
        let expert = 1_572_864.0;
        let pcie = (1.5e9, 30e-6);
        let fast_net = (1.25e9, 200e-6); // 10 Gbps
        let slow_net = (1.25e8, 200e-6); // 1 Gbps
        let run = |n_nodes, net, staged| {
            simulate_remote_cluster(n_nodes, 4, 32, expert, 0.3, staged, 2e-3, pcie, net, 2, 11)
        };
        // single node: no peers, nothing ever crosses the network
        let solo = run(1, slow_net, 0.0);
        assert_eq!(solo.remote_fetches, 0);
        assert_eq!(solo.net_bytes, 0.0);
        // shard across 4 nodes: ~3/4 of misses are peer-resident
        let four = run(4, fast_net, 0.0);
        assert!(four.remote_fetches > 0);
        assert!(four.net_bytes > 0.0);
        // network time queues on the NET link, not the PCIe one: a 10x
        // slower interconnect slows the cluster while the single-node
        // run — which never touches it — is bit-identical
        let four_slow = run(4, slow_net, 0.0);
        assert!(
            four_slow.tps() < four.tps(),
            "slow net {} !< fast net {}",
            four_slow.tps(),
            four.tps()
        );
        let solo_again = run(1, fast_net, 0.0);
        assert_eq!(solo.tokens, solo_again.tokens);
        assert!((solo.total_time - solo_again.total_time).abs() < 1e-12);
        // cross-tier staging takes peer fetches off the demand path
        let staged = run(4, slow_net, 0.9);
        assert!(staged.staged_hits > 0);
        assert!(
            staged.tps() > four_slow.tps(),
            "staged {} !> unstaged {}",
            staged.tps(),
            four_slow.tps()
        );
        assert!(staged.net_bytes < four_slow.net_bytes);
        // utilizations are sane
        let u = four_slow.net_utilization(4);
        assert!((0.0..=1.0).contains(&u), "net utilization {u}");
    }

    #[test]
    fn prefill_scales_with_prompt() {
        let (hw, model, _) = setup();
        let sys = SimSystem::hobbit([0.65, 0.05, 0.10, 0.20]);
        let p16 = simulate_prefill(&sys, &hw, &model, 16, 1).latency;
        let p128 = simulate_prefill(&sys, &hw, &model, 128, 1).latency;
        assert!(p128 > p16);
    }

    #[test]
    fn fiddler_prefill_explodes_with_expert_count() {
        let hw = SimHardware::rtx4090();
        let fd = SimSystem::fiddler(16.0);
        let mix = simulate_prefill(&fd, &hw, &SimModel::mixtral_8x7b(), 128, 1).latency;
        let phi = simulate_prefill(&fd, &hw, &SimModel::phi_moe(), 128, 1).latency;
        assert!(phi > 1.5 * mix, "phi {phi} vs mixtral {mix}");
    }

    /// A workload whose *full-precision* service rate is `overload`× the
    /// offered arrival rate (overload > 1 means arrivals outrun service).
    fn overload_workload(overload: f64) -> (crate::workload::WorkloadConfig, f64, f64, f64) {
        // hi-tier service ≈ prompt·prefill + output·tau_hi = 32·0.2ms +
        // 16·4ms = 70.4 ms/request → capacity ≈ 14.2 rps at full precision
        let tau_hi = 4e-3;
        let tau_lo = 1e-3; // 4× fewer bytes per fetch at the lo tier
        let prefill_tok = 2e-4;
        let service = 32.0 * prefill_tok + 16.0 * tau_hi;
        let cfg = crate::workload::WorkloadConfig {
            mean_rps: overload / service,
            burstiness: 0.3,
            diurnal_period_s: 20.0,
            duration_s: 60.0,
            prompt_mean: 32.0,
            prompt_sigma: 0.4,
            prompt_max: 128,
            output_mean: 16.0,
            output_sigma: 0.3,
            output_max: 64,
            seed: 0xde5_10ad,
        };
        (cfg, tau_hi, tau_lo, prefill_tok)
    }

    #[test]
    fn open_loop_ladder_holds_goodput_at_2x_overload() {
        // the acceptance criterion, in its deterministic DES form: at 2×
        // sustained overload the precision-first ladder keeps ≥ 1.5× the
        // goodput-under-SLO of the no-ladder baseline
        let (cfg, tau_hi, tau_lo, pf) = overload_workload(2.0);
        let with = simulate_open_loop(&cfg, 32, 0.25, true, tau_hi, tau_lo, pf, 0.5);
        let without = simulate_open_loop(&cfg, 32, 0.25, false, tau_hi, tau_lo, pf, 0.5);
        assert!(with.shed_rounds > 0, "ladder never engaged");
        assert_eq!(without.shed_rounds, 0);
        assert!(
            with.goodput_tps >= 1.5 * without.goodput_tps,
            "ladder {} !>= 1.5 × no-ladder {}",
            with.goodput_tps,
            without.goodput_tps
        );
        // degrading precision also flattens the TTFT tail
        assert!(with.ttft_p99 < without.ttft_p99);
        // both runs stay within the admission bound (rejection is the
        // model's availability guarantee, not an error)
        assert_eq!(with.offered, with.admitted + with.rejected);
    }

    #[test]
    fn open_loop_light_load_is_undegraded() {
        // at ≤ 1× load nothing is rejected and the ladder never engages:
        // the fast path is bit-identical to a ladderless server
        let (cfg, tau_hi, tau_lo, pf) = overload_workload(0.5);
        let r = simulate_open_loop(&cfg, 64, 0.25, true, tau_hi, tau_lo, pf, 0.5);
        assert_eq!(r.rejected, 0, "rejections at light load");
        assert_eq!(r.shed_rounds, 0, "precision shed at light load");
        assert_eq!(r.slo_met, r.admitted, "SLO misses at light load");
        assert!(r.ttft_p999 <= 0.5);
    }

    #[test]
    fn open_loop_rejections_bound_the_queue() {
        // a tiny bound under heavy overload: rejections absorb the excess
        // and the tail of *admitted* requests stays bounded by the queue
        let (cfg, tau_hi, tau_lo, pf) = overload_workload(4.0);
        let r = simulate_open_loop(&cfg, 4, 0.25, true, tau_hi, tau_lo, pf, 0.5);
        assert!(r.rejected > 0);
        assert!(r.admitted > 0);
        // worst admitted wait ≤ (limit requests ahead) × (worst service)
        let worst_service =
            cfg.prompt_max as f64 * pf + cfg.output_max as f64 * tau_hi;
        assert!(
            r.ttft_p999 <= 4.0 * worst_service + worst_service,
            "p999 {} vs bound {}",
            r.ttft_p999,
            5.0 * worst_service
        );
    }
}
