//! The remote expert tier: multi-node expert sharding with peer fetch
//! over a modeled network link.
//!
//! One HOBBIT process no longer has to hold every expert in host RAM.
//! Peers run [`shard::ShardServer`] — a threaded line-protocol front-end
//! in the `server.rs` style whose verb is `EXPERT <layer> <expert>
//! <precision> [offset]`, streaming the raw record bytes back in chunks —
//! and each peer owns a disjoint [`ShardSpec`] slice of the flat expert
//! index space. The inference process plugs a [`tiered::TieredStore`]
//! into the loader where `ExpertStore` used to sit, extending the memory
//! hierarchy to the full
//!
//! ```text
//!   HBM (expert cache)  <-  DRAM (local shard + staged records)
//!                       <-  peer (EXPERT protocol over the network link)
//!                       <-  disk (experts_*.bin byte ranges)
//! ```
//!
//! Network bytes are charged against a *second* `memory::LinkArbiter`
//! link class (its own `--net-gbps` budget, the same 4:1
//! on-demand-vs-prefetch weighting), so network and PCIe bandwidth
//! arbitrate independently: a peer fetch saturating the NIC model never
//! steals modeled PCIe time from a local DRAM->HBM copy, and vice versa.
//!
//! Robustness is first-class: every client-side read goes through
//! [`transport`]'s connect/read timeouts and bounded retry with backoff,
//! and a peer that stays dead is circuit-broken for a cooldown while its
//! records are served from the local disk tier (`peer_failovers` counts
//! the degradation). A dead peer slows the system; it never wedges it.

pub mod shard;
pub mod tiered;
pub mod transport;

pub use shard::ShardServer;
pub use tiered::{FetchTier, RecordRef, RemoteCounters, TieredStore};
pub use transport::RetryPolicy;

use std::fmt;

/// A set of flat expert indices (`layer * n_experts + expert`) owned by
/// one node. Parsed from `all`, `none`, or comma-separated inclusive
/// ranges like `0-5,8,10-11`.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct ShardSpec {
    /// inclusive (start, end) ranges over flat indices; ignored when `all`
    ranges: Vec<(u32, u32)>,
    all: bool,
}

impl ShardSpec {
    /// The whole expert set (single-node default).
    pub fn all() -> Self {
        Self { ranges: Vec::new(), all: true }
    }

    /// No experts (a pure client node; peers must cover everything).
    pub fn none() -> Self {
        Self::default()
    }

    pub fn is_all(&self) -> bool {
        self.all
    }

    pub fn is_none(&self) -> bool {
        !self.all && self.ranges.is_empty()
    }

    pub fn parse(s: &str) -> Result<Self, String> {
        let s = s.trim();
        match s {
            "all" => return Ok(Self::all()),
            "" | "none" => return Ok(Self::none()),
            _ => {}
        }
        let mut ranges = Vec::new();
        for seg in s.split(',') {
            let seg = seg.trim();
            if seg.is_empty() {
                return Err(format!("empty segment in shard spec '{s}'"));
            }
            let (a, b) = match seg.split_once('-') {
                Some((a, b)) => (a, b),
                None => (seg, seg),
            };
            let lo: u32 = a.trim().parse().map_err(|_| format!("bad shard index '{a}'"))?;
            let hi: u32 = b.trim().parse().map_err(|_| format!("bad shard index '{b}'"))?;
            if lo > hi {
                return Err(format!("inverted shard range '{seg}'"));
            }
            ranges.push((lo, hi));
        }
        ranges.sort_unstable();
        Ok(Self { ranges, all: false })
    }

    /// Does this shard hold the flat expert index?
    pub fn contains(&self, flat: usize) -> bool {
        if self.all {
            return true;
        }
        let flat = flat as u32;
        self.ranges.iter().any(|&(lo, hi)| lo <= flat && flat <= hi)
    }

    /// Add this shard's coverage counts into `cover` (one slot per flat
    /// index); indices beyond `cover.len()` are an error (shard names an
    /// expert the model does not have).
    fn accumulate(&self, cover: &mut [u32]) -> Result<(), String> {
        if self.all {
            for c in cover.iter_mut() {
                *c += 1;
            }
            return Ok(());
        }
        for &(lo, hi) in &self.ranges {
            if hi as usize >= cover.len() {
                return Err(format!(
                    "shard range {lo}-{hi} exceeds expert count {}",
                    cover.len()
                ));
            }
            for c in &mut cover[lo as usize..=hi as usize] {
                *c += 1;
            }
        }
        Ok(())
    }

    /// Validate that `local` plus `peers` exactly partition the
    /// `total`-sized flat expert space: every expert owned once, none
    /// owned twice, none unowned. This is the startup gate — a bad
    /// assignment is a config error, not a runtime miss.
    pub fn validate_partition(
        local: &ShardSpec,
        peers: &[&ShardSpec],
        total: usize,
    ) -> Result<(), String> {
        let mut cover = vec![0u32; total];
        local.accumulate(&mut cover)?;
        for p in peers {
            p.accumulate(&mut cover)?;
        }
        for (i, &c) in cover.iter().enumerate() {
            if c == 0 {
                return Err(format!(
                    "expert shard assignment incomplete: flat expert {i} owned by no node"
                ));
            }
            if c > 1 {
                return Err(format!(
                    "expert shard assignment overlaps: flat expert {i} owned by {c} nodes"
                ));
            }
        }
        Ok(())
    }
}

impl fmt::Display for ShardSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.all {
            return write!(f, "all");
        }
        if self.ranges.is_empty() {
            return write!(f, "none");
        }
        for (i, &(lo, hi)) in self.ranges.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            if lo == hi {
                write!(f, "{lo}")?;
            } else {
                write!(f, "{lo}-{hi}")?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_spec_parse_roundtrip() {
        assert!(ShardSpec::parse("all").unwrap().is_all());
        assert!(ShardSpec::parse("none").unwrap().is_none());
        assert!(ShardSpec::parse("").unwrap().is_none());
        let s = ShardSpec::parse("0-5,8,10-11").unwrap();
        assert!(s.contains(0) && s.contains(5) && s.contains(8) && s.contains(10));
        assert!(!s.contains(6) && !s.contains(9) && !s.contains(12));
        assert_eq!(s.to_string(), "0-5,8,10-11");
        assert_eq!(ShardSpec::all().to_string(), "all");
        assert_eq!(ShardSpec::none().to_string(), "none");
    }

    #[test]
    fn shard_spec_rejects_garbage() {
        assert!(ShardSpec::parse("5-2").is_err(), "inverted range");
        assert!(ShardSpec::parse("a-b").is_err());
        assert!(ShardSpec::parse("1,,2").is_err());
    }

    #[test]
    fn partition_validation() {
        let a = ShardSpec::parse("0-5").unwrap();
        let b = ShardSpec::parse("6-11").unwrap();
        ShardSpec::validate_partition(&a, &[&b], 12).unwrap();
        ShardSpec::validate_partition(&ShardSpec::none(), &[&a, &b], 12).unwrap();
        ShardSpec::validate_partition(&ShardSpec::all(), &[], 12).unwrap();
        // gap: expert 11 unowned
        let short = ShardSpec::parse("6-10").unwrap();
        let err = ShardSpec::validate_partition(&a, &[&short], 12).unwrap_err();
        assert!(err.contains("incomplete"), "{err}");
        // overlap: expert 5 owned twice
        let over = ShardSpec::parse("5-11").unwrap();
        let err = ShardSpec::validate_partition(&a, &[&over], 12).unwrap_err();
        assert!(err.contains("overlap"), "{err}");
        // out of range
        let big = ShardSpec::parse("0-99").unwrap();
        assert!(ShardSpec::validate_partition(&big, &[], 12).is_err());
    }
}
