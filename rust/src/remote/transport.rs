//! Client-side TCP transport with bounded everything: connect timeout,
//! read/write timeouts, and bounded retry with exponential backoff.
//!
//! Before this module, `server::client_request` would block forever on a
//! hung peer (no connect timeout, unbounded `read_line`). Every
//! client-side read in the crate — the GEN/STATS client and the remote
//! expert tier — now goes through these helpers, so the worst case for
//! any network operation is `attempts * (connect_timeout + io_timeout)`
//! plus backoff, never a wedge.

use std::io::{self, BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

/// Timeout and retry budget for one logical client operation.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// per-attempt TCP connect timeout
    pub connect_timeout: Duration,
    /// per-attempt read/write timeout on the connected stream
    pub io_timeout: Duration,
    /// total attempts (>= 1): 1 try + (attempts - 1) retries
    pub attempts: u32,
    /// sleep before the first retry; doubles each further retry
    pub backoff: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self {
            connect_timeout: Duration::from_secs(1),
            io_timeout: Duration::from_secs(5),
            attempts: 3,
            backoff: Duration::from_millis(50),
        }
    }
}

impl RetryPolicy {
    /// Tight budgets for localhost peers and tests: a dead peer is
    /// detected in well under a second.
    pub fn fast() -> Self {
        Self {
            connect_timeout: Duration::from_millis(200),
            io_timeout: Duration::from_millis(1000),
            attempts: 2,
            backoff: Duration::from_millis(10),
        }
    }
}

/// Connect with the policy's connect timeout and arm the stream's
/// read/write timeouts. Tries every resolved address once.
pub fn connect(addr: &str, policy: &RetryPolicy) -> io::Result<TcpStream> {
    let mut last: Option<io::Error> = None;
    for sa in addr.to_socket_addrs()? {
        match TcpStream::connect_timeout(&sa, policy.connect_timeout) {
            Ok(s) => {
                s.set_read_timeout(Some(policy.io_timeout))?;
                s.set_write_timeout(Some(policy.io_timeout))?;
                let _ = s.set_nodelay(true);
                return Ok(s);
            }
            Err(e) => last = Some(e),
        }
    }
    Err(last.unwrap_or_else(|| {
        io::Error::new(io::ErrorKind::AddrNotAvailable, format!("{addr}: no addresses"))
    }))
}

/// Run `op` up to `policy.attempts` times with exponential backoff
/// between tries. Returns the final result and the number of retries
/// spent (0 = first try succeeded).
pub fn with_retries<T>(
    policy: &RetryPolicy,
    mut op: impl FnMut() -> io::Result<T>,
) -> (io::Result<T>, u32) {
    let attempts = policy.attempts.max(1);
    let mut retries = 0u32;
    let mut delay = policy.backoff;
    loop {
        match op() {
            Ok(v) => return (Ok(v), retries),
            Err(e) => {
                if retries + 1 >= attempts {
                    return (Err(e), retries);
                }
                std::thread::sleep(delay);
                delay = delay.saturating_mul(2);
                retries += 1;
            }
        }
    }
}

/// One-line request, one-line response, full timeout/retry cover. The
/// transport behind `server::client_request`.
pub fn request_line(addr: &str, line: &str, policy: &RetryPolicy) -> io::Result<String> {
    let (res, _retries) = with_retries(policy, || {
        let mut s = connect(addr, policy)?;
        s.write_all(line.as_bytes())?;
        if !line.ends_with('\n') {
            s.write_all(b"\n")?;
        }
        let mut reader = BufReader::new(s);
        let mut out = String::new();
        reader.read_line(&mut out)?;
        if out.is_empty() {
            return Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "connection closed before response line",
            ));
        }
        Ok(out.trim_end().to_string())
    });
    res
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;
    use std::time::Instant;

    #[test]
    fn retries_are_bounded_and_counted() {
        let policy =
            RetryPolicy { attempts: 3, backoff: Duration::from_millis(1), ..RetryPolicy::fast() };
        let mut calls = 0;
        let (res, retries) = with_retries(&policy, || {
            calls += 1;
            Err::<(), _>(io::Error::new(io::ErrorKind::ConnectionRefused, "nope"))
        });
        assert!(res.is_err());
        assert_eq!(calls, 3, "attempts bound the tries");
        assert_eq!(retries, 2);

        let mut calls = 0;
        let (res, retries) = with_retries(&policy, || {
            calls += 1;
            if calls < 2 {
                Err(io::Error::new(io::ErrorKind::ConnectionRefused, "nope"))
            } else {
                Ok(7)
            }
        });
        assert_eq!(res.unwrap(), 7);
        assert_eq!(retries, 1);
    }

    #[test]
    fn dead_port_fails_fast() {
        // bind-then-drop guarantees a port with no listener
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let policy = RetryPolicy { attempts: 2, ..RetryPolicy::fast() };
        let t0 = Instant::now();
        assert!(request_line(&addr, "PING", &policy).is_err());
        // 2 attempts * 200ms connect budget + 10ms backoff, with slack;
        // localhost refusals return immediately so this is far quicker.
        assert!(t0.elapsed() < Duration::from_secs(3), "took {:?}", t0.elapsed());
    }

    #[test]
    fn silent_server_times_out_instead_of_hanging() {
        // a listener that accepts and then never writes a byte
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap().to_string();
        let guard = std::thread::spawn(move || {
            // hold every accepted socket open, silently, until test end
            let mut held = Vec::new();
            while let Ok((s, _)) = l.accept() {
                held.push(s);
                if held.len() >= 2 {
                    break;
                }
            }
        });
        let policy = RetryPolicy {
            io_timeout: Duration::from_millis(100),
            attempts: 2,
            backoff: Duration::from_millis(1),
            ..RetryPolicy::fast()
        };
        let t0 = Instant::now();
        assert!(request_line(&addr, "STATS", &policy).is_err());
        assert!(
            t0.elapsed() < Duration::from_secs(3),
            "read timeout must bound the wait, took {:?}",
            t0.elapsed()
        );
        drop(guard); // detach: listener thread exits once both conns arrive
    }
}
