//! The tiered expert store: DRAM <- peer <- disk behind one fetch call.
//!
//! [`TieredStore`] wraps the process-local [`ExpertStore`] (the DRAM
//! tier, masked to the node's [`ShardSpec`]) with two lower tiers:
//!
//! * **peer** — the shard owner, reached through the `EXPERT` protocol
//!   ([`crate::remote::shard`]) with every body chunk charged against a
//!   dedicated network [`ThrottledCopier`] (the second link class: its
//!   `LinkArbiter` splits `--net-gbps` among concurrent remote fetches
//!   with the same 4:1 on-demand-vs-prefetch weighting as PCIe, but the
//!   two links never share a budget);
//! * **disk** — byte-range reads from the local `experts_*.bin` files.
//!   Disk always holds everything, which is what makes peer death a
//!   slowdown instead of a wedge: a peer that fails its bounded retries
//!   is circuit-broken for a cooldown and its records come from disk,
//!   counted in `peer_failovers`.
//!
//! Records fetched from a peer land in a bounded **staged** side-cache —
//! the peer -> DRAM leg of cross-tier prefetching. The predictor stages
//! ahead of demand through [`TieredStore::stage_async`] (a dedicated
//! stager thread, network charged at prefetch weight), and chunk-level
//! preemption resumes re-read the staged copy instead of re-downloading.
//!
//! The single-node configuration ([`TieredStore::local_only`]) keeps the
//! exact pre-remote behavior: every fetch is a borrow from the local
//! store, no staging, no network, zero overhead.

use std::collections::{HashMap, HashSet, VecDeque};
use std::io::{Read, Seek, SeekFrom};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::{ModelConfig, RemoteConfig};
use crate::faults::FaultPlan;
use crate::memory::{LinkModel, ThrottledCopier, PREFETCH_WEIGHT};
use crate::metrics::LoaderStats;
use crate::model::ExpertStore;
use crate::remote::shard;
use crate::remote::transport::RetryPolicy;
use crate::remote::ShardSpec;
use crate::{ExpertKey, Precision};

/// Which tier would (or did) serve a record.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FetchTier {
    /// process-local store (inside the local shard)
    Dram,
    /// the staged side-cache (already pulled from a peer)
    Staged,
    /// a live peer owning the shard
    Peer,
    /// local disk byte-range (peer down or no owner)
    Disk,
}

/// Record bytes from whichever tier served them: a borrow from the local
/// store, or a shared copy (staged / peer / disk).
pub enum RecordRef<'a> {
    Local(&'a [u8]),
    Shared(Arc<Vec<u8>>),
}

impl RecordRef<'_> {
    pub fn as_slice(&self) -> &[u8] {
        match self {
            RecordRef::Local(b) => b,
            RecordRef::Shared(b) => b,
        }
    }

    pub fn len(&self) -> usize {
        self.as_slice().len()
    }

    pub fn is_empty(&self) -> bool {
        self.as_slice().is_empty()
    }

    pub fn to_vec(&self) -> Vec<u8> {
        self.as_slice().to_vec()
    }
}

/// Remote-tier counters (snapshot of the live atomics).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RemoteCounters {
    /// records pulled over the network (demand + staging)
    pub remote_fetches: u64,
    /// bytes pulled over the network
    pub remote_bytes: u64,
    /// transport retries spent on successful remote fetches
    pub remote_retries: u64,
    /// demand fetches a peer should have served but disk did (degraded tier)
    pub peer_failovers: u64,
    /// fetches answered by the staged side-cache (cross-tier prefetch hits)
    pub staged_hits: u64,
    /// records read from the local disk tier
    pub disk_fetches: u64,
    /// records that failed their checksum at a tier boundary
    pub integrity_failures: u64,
    /// verified records served from a lower tier after a corrupt one
    pub integrity_refetches: u64,
}

#[derive(Default)]
struct RemoteStats {
    remote_fetches: AtomicU64,
    remote_bytes: AtomicU64,
    remote_retries: AtomicU64,
    peer_failovers: AtomicU64,
    staged_hits: AtomicU64,
    disk_fetches: AtomicU64,
    integrity_failures: AtomicU64,
    integrity_refetches: AtomicU64,
}

impl RemoteStats {
    fn snapshot(&self) -> RemoteCounters {
        RemoteCounters {
            remote_fetches: self.remote_fetches.load(Ordering::Relaxed),
            remote_bytes: self.remote_bytes.load(Ordering::Relaxed),
            remote_retries: self.remote_retries.load(Ordering::Relaxed),
            peer_failovers: self.peer_failovers.load(Ordering::Relaxed),
            staged_hits: self.staged_hits.load(Ordering::Relaxed),
            disk_fetches: self.disk_fetches.load(Ordering::Relaxed),
            integrity_failures: self.integrity_failures.load(Ordering::Relaxed),
            integrity_refetches: self.integrity_refetches.load(Ordering::Relaxed),
        }
    }
}

/// One configured peer with its circuit-breaker state.
struct Peer {
    addr: String,
    shard: ShardSpec,
    /// circuit breaker: while set and in the future, skip straight to disk
    down_until: Mutex<Option<Instant>>,
}

impl Peer {
    fn is_up(&self) -> bool {
        match *self.down_until.lock().unwrap() {
            Some(t) => Instant::now() >= t,
            None => true,
        }
    }

    fn mark_down(&self, cooldown: Duration) {
        *self.down_until.lock().unwrap() = Some(Instant::now() + cooldown);
    }

    fn mark_up(&self) {
        *self.down_until.lock().unwrap() = None;
    }
}

/// Local disk tier: byte-range reads from the weight files the local
/// store was loaded from. Always covers every expert — the failover
/// floor of the hierarchy.
struct DiskTier {
    dir: PathBuf,
    cfg: ModelConfig,
}

impl DiskTier {
    fn read(&self, key: ExpertKey, p: Precision) -> std::io::Result<Vec<u8>> {
        let rb = self.cfg.bytes_for(p);
        let mut f = std::fs::File::open(self.dir.join(format!("experts_{}.bin", p.name())))?;
        f.seek(SeekFrom::Start((key.index(self.cfg.n_experts) * rb) as u64))?;
        let mut buf = vec![0u8; rb];
        f.read_exact(&mut buf)?;
        Ok(buf)
    }
}

/// Bounded FIFO side-cache of records pulled from lower tiers.
struct StagedCache {
    map: HashMap<(ExpertKey, Precision), Arc<Vec<u8>>>,
    order: VecDeque<(ExpertKey, Precision)>,
    cap: usize,
}

impl StagedCache {
    fn new(cap: usize) -> Self {
        Self { map: HashMap::new(), order: VecDeque::new(), cap: cap.max(1) }
    }

    fn get(&self, k: &(ExpertKey, Precision)) -> Option<Arc<Vec<u8>>> {
        self.map.get(k).cloned()
    }

    fn insert(&mut self, k: (ExpertKey, Precision), v: Arc<Vec<u8>>) {
        if self.map.insert(k, v).is_none() {
            self.order.push_back(k);
        }
        while self.map.len() > self.cap {
            if let Some(old) = self.order.pop_front() {
                self.map.remove(&old);
            } else {
                break;
            }
        }
    }

    /// Quarantine one entry (a staged copy that failed its checksum).
    fn remove(&mut self, k: &(ExpertKey, Precision)) {
        if self.map.remove(k).is_some() {
            self.order.retain(|e| e != k);
        }
    }
}

/// Everything the fetch path and the stager thread share.
struct Core {
    local: Arc<ExpertStore>,
    local_shard: ShardSpec,
    peers: Vec<Peer>,
    disk: Option<DiskTier>,
    net: Option<Arc<ThrottledCopier>>,
    staged: Mutex<StagedCache>,
    /// stage_async dedup: keys queued but not yet staged
    queued: Mutex<HashSet<(ExpertKey, Precision)>>,
    retry: RetryPolicy,
    cooldown: Duration,
    chunk_bytes: usize,
    stats: RemoteStats,
    /// deterministic fault injection (disk flips here; the loader pulls
    /// the same plan for transfer faults); None in production
    faults: Option<Arc<FaultPlan>>,
}

impl Core {
    fn flat(&self, key: ExpertKey) -> usize {
        key.index(self.local.config().n_experts)
    }

    /// Verify a full record against the local integrity table.
    fn verify(&self, key: ExpertKey, p: Precision, bytes: &[u8]) -> bool {
        self.local.integrity().verify(self.flat(key), p, bytes)
    }

    fn peer_for(&self, key: ExpertKey) -> Option<&Peer> {
        let flat = self.flat(key);
        self.peers.iter().find(|p| p.shard.contains(flat))
    }

    fn tier_of(&self, key: ExpertKey, p: Precision) -> FetchTier {
        if self.peers.is_empty() || self.local_shard.contains(self.flat(key)) {
            return FetchTier::Dram;
        }
        if self.staged.lock().unwrap().get(&(key, p)).is_some() {
            return FetchTier::Staged;
        }
        match self.peer_for(key) {
            Some(peer) if peer.is_up() => FetchTier::Peer,
            _ => FetchTier::Disk,
        }
    }

    /// Pull one record over the network, charging the network link class
    /// at `weight` per chunk. Returns the bytes and the retries spent.
    fn fetch_from_peer(
        &self,
        peer: &Peer,
        key: ExpertKey,
        p: Precision,
        weight: f64,
    ) -> std::io::Result<(Vec<u8>, u32)> {
        let expect = self.local.record_bytes(p);
        let rec = match &self.net {
            Some(net) => {
                let grant = net.lane(weight);
                net.charge_latency();
                shard::fetch_record(
                    &peer.addr,
                    key,
                    p,
                    0,
                    expect,
                    self.chunk_bytes,
                    &self.retry,
                    &mut |n, spent| net.charge_chunk(&grant, n, spent),
                )?
            }
            None => shard::fetch_record(
                &peer.addr,
                key,
                p,
                0,
                expect,
                self.chunk_bytes,
                &self.retry,
                &mut |_, _| {},
            )?,
        };
        if let Some(net) = &self.net {
            net.note_transfer();
        }
        Ok((rec.bytes, rec.retries))
    }

    /// The demand fetch path: DRAM -> staged -> peer -> disk -> (last
    /// resort) the local buffer. Infallible by construction — a dead
    /// peer degrades the tier, it never fails the fetch, and a record
    /// that fails its checksum at any boundary is quarantined and healed
    /// from the next tier down (corruption costs latency, never
    /// correctness).
    fn fetch(&self, key: ExpertKey, p: Precision, weight: f64) -> RecordRef<'_> {
        if self.peers.is_empty() || self.local_shard.contains(self.flat(key)) {
            return RecordRef::Local(self.local.record(key, p));
        }
        // set once a tier serves corrupt bytes; the first verified record
        // from a lower tier then counts as an integrity re-fetch
        let mut healing = false;
        // bind outside the if-let: the lock guard must drop before the
        // quarantine path re-locks to remove the entry
        let staged_hit = self.staged.lock().unwrap().get(&(key, p));
        if let Some(b) = staged_hit {
            if self.verify(key, p, &b) {
                self.stats.staged_hits.fetch_add(1, Ordering::Relaxed);
                return RecordRef::Shared(b);
            }
            // quarantine the corrupt staged copy and heal from below
            self.staged.lock().unwrap().remove(&(key, p));
            self.stats.integrity_failures.fetch_add(1, Ordering::Relaxed);
            healing = true;
        }
        if let Some(peer) = self.peer_for(key) {
            if peer.is_up() {
                match self.fetch_from_peer(peer, key, p, weight) {
                    Ok((bytes, retries)) if self.verify(key, p, &bytes) => {
                        peer.mark_up();
                        self.stats.remote_fetches.fetch_add(1, Ordering::Relaxed);
                        self.stats.remote_bytes.fetch_add(bytes.len() as u64, Ordering::Relaxed);
                        self.stats.remote_retries.fetch_add(retries as u64, Ordering::Relaxed);
                        if healing {
                            self.stats.integrity_refetches.fetch_add(1, Ordering::Relaxed);
                        }
                        let arc = Arc::new(bytes);
                        self.staged.lock().unwrap().insert((key, p), arc.clone());
                        return RecordRef::Shared(arc);
                    }
                    Ok(_) => {
                        // the frame checksum matched what the peer sent, but
                        // the table says the peer's copy itself is corrupt:
                        // break the circuit and heal from disk
                        self.stats.integrity_failures.fetch_add(1, Ordering::Relaxed);
                        peer.mark_down(self.cooldown);
                        self.stats.peer_failovers.fetch_add(1, Ordering::Relaxed);
                        healing = true;
                    }
                    Err(e) => {
                        // retries exhausted: break the circuit so the next
                        // fetches skip the connect/read budget entirely
                        if is_integrity_error(&e) {
                            self.stats.integrity_failures.fetch_add(1, Ordering::Relaxed);
                            healing = true;
                        }
                        peer.mark_down(self.cooldown);
                        self.stats.peer_failovers.fetch_add(1, Ordering::Relaxed);
                    }
                }
            } else {
                // peer in cooldown: every fetch it should have served is a
                // degraded-tier fetch
                self.stats.peer_failovers.fetch_add(1, Ordering::Relaxed);
            }
        }
        if let Some(disk) = &self.disk {
            if let Ok(mut bytes) = disk.read(key, p) {
                if let Some(plan) = &self.faults {
                    plan.on_disk_read(&mut bytes);
                }
                if self.verify(key, p, &bytes) {
                    self.stats.disk_fetches.fetch_add(1, Ordering::Relaxed);
                    if healing {
                        self.stats.integrity_refetches.fetch_add(1, Ordering::Relaxed);
                    }
                    let arc = Arc::new(bytes);
                    self.staged.lock().unwrap().insert((key, p), arc.clone());
                    return RecordRef::Shared(arc);
                }
                // corrupt disk read: never serve it, heal from the local
                // in-memory copy below
                self.stats.integrity_failures.fetch_add(1, Ordering::Relaxed);
                healing = true;
            }
        }
        // the local store physically holds every record (the shard mask is
        // a modeling decision) and was checksum-verified at load, so
        // correctness survives even a vanished weights directory
        if healing {
            self.stats.integrity_refetches.fetch_add(1, Ordering::Relaxed);
        }
        RecordRef::Local(self.local.record(key, p))
    }
}

/// Is this fetch error a detected corruption (as opposed to a dead or
/// unreachable peer)?
fn is_integrity_error(e: &std::io::Error) -> bool {
    e.kind() == std::io::ErrorKind::InvalidData && e.to_string().contains("checksum mismatch")
}

/// The loader-facing tiered store. See the module docs for the tier
/// ordering and failure semantics.
pub struct TieredStore {
    core: Arc<Core>,
    /// stager thread input; None when no peers are configured
    stager: Option<mpsc::Sender<(ExpertKey, Precision)>>,
}

impl TieredStore {
    /// Single-node wrapper: every fetch is a borrow from `store`, no
    /// network, no staging — the exact pre-remote behavior.
    pub fn local_only(store: Arc<ExpertStore>) -> Self {
        let core = Core {
            local: store,
            local_shard: ShardSpec::all(),
            peers: Vec::new(),
            disk: None,
            net: None,
            staged: Mutex::new(StagedCache::new(1)),
            queued: Mutex::new(HashSet::new()),
            retry: RetryPolicy::default(),
            cooldown: Duration::from_secs(2),
            chunk_bytes: shard::DEFAULT_CHUNK_BYTES,
            stats: RemoteStats::default(),
            faults: None,
        };
        Self { core: Arc::new(core), stager: None }
    }

    /// Attach a fault plan to a single-node store (must be called before
    /// the store is shared — multi-node stores thread the plan through
    /// [`RemoteConfig::faults`] instead, because the stager thread already
    /// holds a reference by the time `from_config` returns).
    pub fn with_faults(mut self, faults: Option<Arc<FaultPlan>>) -> Self {
        if let Some(core) = Arc::get_mut(&mut self.core) {
            core.faults = faults;
        }
        self
    }

    /// The attached fault plan, if any: the loader pulls this for its
    /// transfer/commit fault sites so one plan covers every tier.
    pub fn faults(&self) -> Option<Arc<FaultPlan>> {
        self.core.faults.clone()
    }

    /// The manifest checksum the commit-time verification expects for one
    /// record (from the local store's integrity table).
    pub fn expected_checksum(&self, key: ExpertKey, p: Precision) -> Option<u64> {
        self.core.local.integrity().checksum(self.core.flat(key), p)
    }

    /// Multi-node store: validates the shard partition, builds the
    /// network link class from the config, and spawns the stager thread.
    /// `weights_dir` backs the disk failover tier.
    pub fn from_config(
        store: Arc<ExpertStore>,
        rc: &RemoteConfig,
        weights_dir: &Path,
    ) -> Result<Self> {
        rc.validate(store.config().total_experts()).map_err(anyhow::Error::msg)?;
        if rc.peers.is_empty() {
            return Ok(Self::local_only(store));
        }
        let cfg = store.config().clone();
        let net = Arc::new(ThrottledCopier::new(LinkModel {
            bytes_per_s: rc.net_bw,
            latency_s: rc.net_latency,
        }));
        let core = Arc::new(Core {
            local: store,
            local_shard: rc.local_shard.clone(),
            peers: rc
                .peers
                .iter()
                .map(|p| Peer {
                    addr: p.addr.clone(),
                    shard: p.shard.clone(),
                    down_until: Mutex::new(None),
                })
                .collect(),
            disk: Some(DiskTier { dir: weights_dir.to_path_buf(), cfg }),
            net: Some(net),
            staged: Mutex::new(StagedCache::new(rc.staged_capacity)),
            queued: Mutex::new(HashSet::new()),
            retry: rc.retry,
            cooldown: rc.cooldown,
            chunk_bytes: rc.chunk_bytes.max(1),
            stats: RemoteStats::default(),
            faults: rc.faults.clone(),
        });
        let (tx, rx) = mpsc::channel::<(ExpertKey, Precision)>();
        let stager_core = core.clone();
        std::thread::Builder::new()
            .name("hobbit-stager".into())
            .spawn(move || stager_loop(stager_core, rx))
            .expect("spawn stager");
        Ok(Self { core, stager: Some(tx) })
    }

    pub fn config(&self) -> &ModelConfig {
        self.core.local.config()
    }

    pub fn record_bytes(&self, p: Precision) -> usize {
        self.core.local.record_bytes(p)
    }

    /// True when any expert can live on a peer (multi-node mode).
    pub fn has_remote(&self) -> bool {
        !self.core.peers.is_empty()
    }

    /// The cheapest tier currently holding `(key, p)`.
    pub fn tier_of(&self, key: ExpertKey, p: Precision) -> FetchTier {
        self.core.tier_of(key, p)
    }

    /// Fetch the record bytes from the cheapest tier holding them.
    /// `net_weight` prices any network leg on the network link class
    /// (`memory::ONDEMAND_WEIGHT` / `memory::PREFETCH_WEIGHT`).
    pub fn fetch(&self, key: ExpertKey, p: Precision, net_weight: f64) -> RecordRef<'_> {
        self.core.fetch(key, p, net_weight)
    }

    /// Owned-bytes variant for callers that outlive the borrow (the
    /// engine's cache-bypass reads).
    pub fn fetch_owned(&self, key: ExpertKey, p: Precision, net_weight: f64) -> Vec<u8> {
        self.core.fetch(key, p, net_weight).to_vec()
    }

    /// Queue a peer -> DRAM staging of `(key, p)` ahead of demand (the
    /// predictor's cross-tier prefetch). No-op unless the record's
    /// cheapest tier is a live peer; dedups in-flight requests.
    pub fn stage_async(&self, key: ExpertKey, p: Precision) {
        let Some(tx) = &self.stager else { return };
        if self.core.tier_of(key, p) != FetchTier::Peer {
            return;
        }
        if !self.core.queued.lock().unwrap().insert((key, p)) {
            return; // already queued
        }
        let _ = tx.send((key, p));
    }

    /// Is `(key, p)` already in the staged side-cache?
    pub fn is_staged(&self, key: ExpertKey, p: Precision) -> bool {
        self.core.staged.lock().unwrap().get(&(key, p)).is_some()
    }

    pub fn counters(&self) -> RemoteCounters {
        self.core.stats.snapshot()
    }

    /// Fold the remote counters into a [`LoaderStats`] snapshot (the
    /// residency facade's stats merge point).
    pub fn merge_into(&self, s: &mut LoaderStats) {
        let c = self.counters();
        s.remote_fetches = c.remote_fetches;
        s.remote_bytes = c.remote_bytes;
        s.remote_retries = c.remote_retries;
        s.peer_failovers = c.peer_failovers;
        s.remote_staged_hits = c.staged_hits;
        s.disk_fetches = c.disk_fetches;
        // accumulated, not assigned: the loader counts its own commit-time
        // failures/heals in the same fields
        s.integrity_failures += c.integrity_failures;
        s.integrity_refetches += c.integrity_refetches;
    }

    /// The network link class, when one exists (tests and benches probe
    /// its byte/lane accounting).
    pub fn net_copier(&self) -> Option<&Arc<ThrottledCopier>> {
        self.core.net.as_ref()
    }

    /// Test-only: plant raw bytes in the staged side-cache (simulating a
    /// copy corrupted after it was staged).
    #[cfg(test)]
    fn stage_raw(&self, key: ExpertKey, p: Precision, bytes: Vec<u8>) {
        self.core.staged.lock().unwrap().insert((key, p), Arc::new(bytes));
    }
}

/// The stager thread: pulls queued (key, precision) pairs and fetches
/// them from their peer at prefetch weight into the staged side-cache.
/// Exits when the store (the sender) drops. Staging failures are silent
/// besides the circuit breaker — the demand path will fail over cleanly.
fn stager_loop(core: Arc<Core>, rx: mpsc::Receiver<(ExpertKey, Precision)>) {
    while let Ok((key, p)) = rx.recv() {
        core.queued.lock().unwrap().remove(&(key, p));
        if core.tier_of(key, p) != FetchTier::Peer {
            continue; // raced with a demand fetch, or peer went down
        }
        let Some(peer) = core.peer_for(key) else { continue };
        match core.fetch_from_peer(peer, key, p, PREFETCH_WEIGHT) {
            Ok((bytes, retries)) => {
                core.stats.remote_fetches.fetch_add(1, Ordering::Relaxed);
                core.stats.remote_bytes.fetch_add(bytes.len() as u64, Ordering::Relaxed);
                core.stats.remote_retries.fetch_add(retries as u64, Ordering::Relaxed);
                core.staged.lock().unwrap().insert((key, p), Arc::new(bytes));
            }
            Err(_) => peer.mark_down(core.cooldown),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synth::{tiny_store_config, write_synth_expert_store};
    use crate::remote::ShardServer;

    fn synth_dir(name: &str) -> (ModelConfig, PathBuf) {
        let cfg = tiny_store_config(name);
        let dir = std::env::temp_dir().join(format!("hobbit_tiered_unit_{name}"));
        write_synth_expert_store(&dir, &cfg).unwrap();
        (cfg, dir)
    }

    fn fast_remote(peers: Vec<crate::config::PeerSpec>, local: ShardSpec) -> RemoteConfig {
        RemoteConfig {
            local_shard: local,
            peers,
            retry: RetryPolicy::fast(),
            cooldown: Duration::from_millis(200),
            // fast modeled network so unit tests stay quick
            net_bw: 1e9,
            net_latency: 0.0,
            ..RemoteConfig::default()
        }
    }

    #[test]
    fn local_only_borrows_and_counts_nothing() {
        let (cfg, dir) = synth_dir("local");
        let store = Arc::new(ExpertStore::load(&dir, &cfg).unwrap());
        let tiered = TieredStore::local_only(store.clone());
        let key = ExpertKey::new(2, 1);
        assert_eq!(tiered.tier_of(key, Precision::F32), FetchTier::Dram);
        let rec = tiered.fetch(key, Precision::F32, 4.0);
        assert!(matches!(rec, RecordRef::Local(_)));
        assert_eq!(rec.as_slice(), store.record(key, Precision::F32));
        tiered.stage_async(key, Precision::F32); // no-op, no panic
        assert_eq!(tiered.counters(), RemoteCounters::default());
    }

    #[test]
    fn peer_fetch_stages_and_fails_over_to_disk() {
        let (cfg, dir) = synth_dir("peerpath");
        let store = Arc::new(ExpertStore::load(&dir, &cfg).unwrap());
        // peer owns the top half of the flat space (layers 2-3)
        let server = ShardServer::bind(
            "127.0.0.1:0",
            store.clone(),
            ShardSpec::parse("8-15").unwrap(),
            4096,
        )
        .unwrap();
        let addr = server.serve_background().to_string();
        let rc = fast_remote(
            vec![crate::config::PeerSpec { addr, shard: ShardSpec::parse("8-15").unwrap() }],
            ShardSpec::parse("0-7").unwrap(),
        );
        let tiered = TieredStore::from_config(store.clone(), &rc, &dir).unwrap();

        // local half: DRAM borrow
        let k_local = ExpertKey::new(0, 0);
        assert_eq!(tiered.tier_of(k_local, Precision::Q8), FetchTier::Dram);
        assert!(matches!(tiered.fetch(k_local, Precision::Q8, 4.0), RecordRef::Local(_)));

        // remote half: peer fetch, byte-identical, then staged on re-fetch
        let k_remote = ExpertKey::new(3, 1);
        assert_eq!(tiered.tier_of(k_remote, Precision::Q8), FetchTier::Peer);
        let rec = tiered.fetch(k_remote, Precision::Q8, 4.0);
        assert_eq!(rec.as_slice(), store.record(k_remote, Precision::Q8));
        assert_eq!(tiered.tier_of(k_remote, Precision::Q8), FetchTier::Staged);
        let _ = tiered.fetch(k_remote, Precision::Q8, 4.0);
        let c = tiered.counters();
        assert_eq!(c.remote_fetches, 1, "second fetch must hit staged, not the network");
        assert_eq!(c.staged_hits, 1);
        assert_eq!(c.remote_bytes, store.record_bytes(Precision::Q8) as u64);
        assert_eq!(c.peer_failovers, 0);

        // dead peer: failover to disk, still byte-identical, counted
        let dead = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let rc = fast_remote(
            vec![crate::config::PeerSpec { addr: dead, shard: ShardSpec::parse("8-15").unwrap() }],
            ShardSpec::parse("0-7").unwrap(),
        );
        let tiered = TieredStore::from_config(store.clone(), &rc, &dir).unwrap();
        let rec = tiered.fetch(k_remote, Precision::F32, 4.0);
        assert_eq!(rec.as_slice(), store.record(k_remote, Precision::F32));
        let c = tiered.counters();
        assert!(c.peer_failovers >= 1);
        assert_eq!(c.disk_fetches, 1);
        assert_eq!(c.remote_fetches, 0);
        // circuit broken: the next miss goes straight to disk (fast)
        let t0 = Instant::now();
        let _ = tiered.fetch(ExpertKey::new(2, 2), Precision::F32, 4.0);
        assert!(t0.elapsed() < Duration::from_millis(100), "cooldown must skip the dead peer");
    }

    #[test]
    fn corrupt_staged_copy_is_quarantined_and_healed_from_peer() {
        let (cfg, dir) = synth_dir("stagedheal");
        let store = Arc::new(ExpertStore::load(&dir, &cfg).unwrap());
        let server = ShardServer::bind(
            "127.0.0.1:0",
            store.clone(),
            ShardSpec::parse("8-15").unwrap(),
            4096,
        )
        .unwrap();
        let addr = server.serve_background().to_string();
        let rc = fast_remote(
            vec![crate::config::PeerSpec { addr, shard: ShardSpec::parse("8-15").unwrap() }],
            ShardSpec::parse("0-7").unwrap(),
        );
        let tiered = TieredStore::from_config(store.clone(), &rc, &dir).unwrap();

        // plant a corrupted staged copy: one bit off the real record
        let key = ExpertKey::new(2, 3);
        let mut bad = store.record(key, Precision::Q8).to_vec();
        bad[17] ^= 0x08;
        tiered.stage_raw(key, Precision::Q8, bad);
        assert_eq!(tiered.tier_of(key, Precision::Q8), FetchTier::Staged);

        // the fetch never serves it: quarantined, healed from the peer
        let rec = tiered.fetch(key, Precision::Q8, 4.0);
        assert_eq!(rec.as_slice(), store.record(key, Precision::Q8));
        let c = tiered.counters();
        assert_eq!(c.integrity_failures, 1);
        assert_eq!(c.integrity_refetches, 1);
        assert_eq!(c.staged_hits, 0, "a corrupt staged copy is not a hit");
        assert_eq!(c.remote_fetches, 1);
        // the healed copy replaced the corrupt one in the side-cache
        let _ = tiered.fetch(key, Precision::Q8, 4.0);
        assert_eq!(tiered.counters().staged_hits, 1);
    }

    #[test]
    fn corrupt_peer_heals_from_disk() {
        let (cfg, dir) = synth_dir("peerheal");
        let store = Arc::new(ExpertStore::load(&dir, &cfg).unwrap());
        // the peer flips every reply after the frame checksum is computed,
        // so the client detects it on the wire every time
        let plan = Arc::new(crate::faults::FaultPlan::parse("5:flip@peer#*").unwrap());
        let server = ShardServer::bind(
            "127.0.0.1:0",
            store.clone(),
            ShardSpec::parse("8-15").unwrap(),
            4096,
        )
        .unwrap()
        .with_faults(Some(plan));
        let addr = server.serve_background().to_string();
        let rc = fast_remote(
            vec![crate::config::PeerSpec { addr, shard: ShardSpec::parse("8-15").unwrap() }],
            ShardSpec::parse("0-7").unwrap(),
        );
        let tiered = TieredStore::from_config(store.clone(), &rc, &dir).unwrap();

        let key = ExpertKey::new(3, 2);
        let rec = tiered.fetch(key, Precision::F32, 4.0);
        assert_eq!(rec.as_slice(), store.record(key, Precision::F32));
        let c = tiered.counters();
        assert_eq!(c.integrity_failures, 1);
        assert_eq!(c.integrity_refetches, 1);
        assert_eq!(c.disk_fetches, 1, "heal must come from the disk tier");
        assert!(c.peer_failovers >= 1);
        assert_eq!(c.remote_fetches, 0, "a corrupt remote record never counts as fetched");
    }

    #[test]
    fn corrupt_disk_read_falls_back_to_local_borrow() {
        let (cfg, dir) = synth_dir("diskheal");
        let store = Arc::new(ExpertStore::load(&dir, &cfg).unwrap());
        let dead = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let mut rc = fast_remote(
            vec![crate::config::PeerSpec { addr: dead, shard: ShardSpec::parse("8-15").unwrap() }],
            ShardSpec::parse("0-7").unwrap(),
        );
        rc.faults = Some(Arc::new(crate::faults::FaultPlan::parse("9:flip@disk#1").unwrap()));
        let tiered = TieredStore::from_config(store.clone(), &rc, &dir).unwrap();

        // peer dead, disk read flipped: the last-resort local borrow still
        // returns the correct bytes
        let key = ExpertKey::new(3, 0);
        let rec = tiered.fetch(key, Precision::Q4, 4.0);
        assert_eq!(rec.as_slice(), store.record(key, Precision::Q4));
        let c = tiered.counters();
        assert_eq!(c.integrity_failures, 1);
        assert_eq!(c.integrity_refetches, 1);
        assert_eq!(c.disk_fetches, 0, "a corrupt disk read never counts as served");

        // next fetch: the plan is spent, disk serves clean
        let rec = tiered.fetch(ExpertKey::new(2, 1), Precision::Q4, 4.0);
        assert_eq!(rec.as_slice(), store.record(ExpertKey::new(2, 1), Precision::Q4));
        assert_eq!(tiered.counters().disk_fetches, 1);
    }

    #[test]
    fn partition_validated_at_construction() {
        let (cfg, dir) = synth_dir("badpart");
        let store = Arc::new(ExpertStore::load(&dir, &cfg).unwrap());
        let rc = fast_remote(
            vec![crate::config::PeerSpec {
                addr: "127.0.0.1:1".into(),
                shard: ShardSpec::parse("8-14").unwrap(), // 15 unowned
            }],
            ShardSpec::parse("0-7").unwrap(),
        );
        let err = TieredStore::from_config(store, &rc, &dir).unwrap_err();
        assert!(err.to_string().contains("incomplete"), "{err}");
    }
}
