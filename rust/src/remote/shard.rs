//! The expert shard server and its client: the `EXPERT` verb.
//!
//! A shard server is the `server.rs` front-end idiom applied to weight
//! distribution: a threaded accept loop, one reader thread per
//! connection, a line-oriented request grammar. The verb is
//!
//! ```text
//!   EXPERT <layer> <expert> <precision> [offset]
//! ```
//!
//! answered with `OK <nbytes> <fnv1a64-hex>\n` followed by exactly
//! `nbytes` raw record bytes (the record suffix starting at `offset`,
//! default 0), written in `chunk_bytes`-sized pieces so a slow reader
//! never buffers a whole record in the kernel; errors come back as a
//! single `ERR <reason>\n` line. The frame's checksum field covers the
//! body being sent, so the client detects a record corrupted anywhere on
//! the peer→wire→client path the moment the last byte lands (clients
//! tolerate a missing checksum field from pre-integrity peers). `PING`
//! answers `OK 0\n` (liveness probe). A server only answers for experts
//! inside its [`ShardSpec`] — asking the wrong peer is a protocol error,
//! not a silent wrong answer.
//!
//! The client side, [`fetch_record`], reads the reply through the
//! [`transport`] timeouts with bounded retry, reporting each chunk to a
//! caller-supplied callback so the tiered store can charge the modeled
//! network link without this module knowing about link arbitration.

use std::io::{self, BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::faults::{FaultPlan, PeerFault};
use crate::model::ExpertStore;
use crate::remote::transport::{self, RetryPolicy};
use crate::remote::ShardSpec;
use crate::util::checksum::{fnv1a64, from_hex, to_hex};
use crate::{ExpertKey, Precision};

/// Streaming granularity of record responses (server write side and
/// client read side) unless configured otherwise.
pub const DEFAULT_CHUNK_BYTES: usize = 64 * 1024;

/// A peer-facing expert shard server over one local [`ExpertStore`].
pub struct ShardServer {
    listener: TcpListener,
    store: Arc<ExpertStore>,
    shard: ShardSpec,
    chunk_bytes: usize,
    /// chaos harness: corrupt/truncate replies on a seeded schedule
    /// (`shard-serve --fault-plan`); None in production
    faults: Option<Arc<FaultPlan>>,
}

impl ShardServer {
    pub fn bind(
        addr: &str,
        store: Arc<ExpertStore>,
        shard: ShardSpec,
        chunk_bytes: usize,
    ) -> Result<Self> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding shard server {addr}"))?;
        Ok(Self { listener, store, shard, chunk_bytes: chunk_bytes.max(1), faults: None })
    }

    /// Attach a fault plan: replies corrupt or truncate on its schedule.
    /// The frame checksum is always computed from the *clean* bytes, so
    /// an injected flip is exactly what a real wire corruption looks like
    /// to the client.
    pub fn with_faults(mut self, faults: Option<Arc<FaultPlan>>) -> Self {
        self.faults = faults;
        self
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.listener.local_addr().expect("listener addr")
    }

    /// Threaded accept loop: one connection, one reader thread, requests
    /// served until the client disconnects. Runs forever.
    pub fn serve(&self) -> Result<()> {
        for conn in self.listener.incoming() {
            let stream = match conn {
                Ok(s) => s,
                Err(_) => continue,
            };
            let store = self.store.clone();
            let shard = self.shard.clone();
            let chunk = self.chunk_bytes;
            let faults = self.faults.clone();
            std::thread::spawn(move || {
                let _ = handle_conn(stream, &store, &shard, chunk, faults.as_deref());
            });
        }
        Ok(())
    }

    /// Spawn the accept loop on a background thread (in-process tests and
    /// benches). The listener lives as long as the detached thread.
    pub fn serve_background(self) -> SocketAddr {
        let addr = self.local_addr();
        std::thread::spawn(move || {
            let _ = self.serve();
        });
        addr
    }
}

fn handle_conn(
    stream: TcpStream,
    store: &ExpertStore,
    shard: &ShardSpec,
    chunk_bytes: usize,
    faults: Option<&FaultPlan>,
) -> io::Result<()> {
    // an idle or wedged client may not hold a server thread forever
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    stream.set_write_timeout(Some(Duration::from_secs(30)))?;
    let _ = stream.set_nodelay(true);
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        let req = line.trim();
        if req.is_empty() {
            continue;
        }
        match parse_expert_request(req, store, shard) {
            Ok(Some(body)) => {
                // the frame checksum covers the clean body: anything that
                // changes a byte after this point — wire damage or an
                // injected fault — fails the client's post-read check
                let header = format!("OK {} {}\n", body.len(), to_hex(fnv1a64(body)));
                match faults {
                    Some(plan) => {
                        let mut owned = body.to_vec();
                        let fault = plan.on_peer_reply(&mut owned);
                        let send: &[u8] = match fault {
                            Some(PeerFault::Truncate(keep)) => &owned[..keep],
                            _ => &owned,
                        };
                        writer.write_all(header.as_bytes())?;
                        for piece in send.chunks(chunk_bytes) {
                            writer.write_all(piece)?;
                        }
                        writer.flush()?;
                        if matches!(fault, Some(PeerFault::Truncate(_))) {
                            // a torn stream: drop the connection with the
                            // client starved mid-record
                            return Ok(());
                        }
                    }
                    None => {
                        writer.write_all(header.as_bytes())?;
                        // stream the record in chunks, the unit a slow
                        // peer back-pressures at
                        for piece in body.chunks(chunk_bytes) {
                            writer.write_all(piece)?;
                        }
                        writer.flush()?;
                    }
                }
            }
            Ok(None) => {
                writer.write_all(b"OK 0\n")?; // PING
                writer.flush()?;
            }
            Err(msg) => {
                writer.write_all(format!("ERR {msg}\n").as_bytes())?;
                writer.flush()?;
            }
        }
    }
}

/// Parse + execute one request line against the local store. `Ok(None)`
/// is a PING (no body); `Ok(Some(bytes))` is an EXPERT hit.
fn parse_expert_request<'a>(
    req: &str,
    store: &'a ExpertStore,
    shard: &ShardSpec,
) -> std::result::Result<Option<&'a [u8]>, String> {
    let mut parts = req.split_whitespace();
    match parts.next() {
        Some("PING") => Ok(None),
        Some("EXPERT") => {
            let layer: u32 = parts
                .next()
                .and_then(|s| s.parse().ok())
                .ok_or("EXPERT needs <layer> <expert> <precision> [offset]")?;
            let expert: u32 = parts
                .next()
                .and_then(|s| s.parse().ok())
                .ok_or("EXPERT needs <layer> <expert> <precision> [offset]")?;
            let prec = parts
                .next()
                .and_then(Precision::from_name)
                .ok_or("bad precision (f32|q8|q4|q2)")?;
            let offset: usize = match parts.next() {
                Some(s) => s.parse().map_err(|_| "bad offset")?,
                None => 0,
            };
            if parts.next().is_some() {
                return Err("trailing arguments".into());
            }
            let cfg = store.config();
            if layer >= cfg.n_layers || expert >= cfg.n_experts {
                return Err(format!("expert ({layer},{expert}) out of model range"));
            }
            let key = ExpertKey::new(layer, expert);
            if !shard.contains(key.index(cfg.n_experts)) {
                return Err(format!("expert ({layer},{expert}) not in this shard"));
            }
            let rec = store.record(key, prec);
            if offset > rec.len() {
                return Err(format!("offset {offset} beyond record ({} bytes)", rec.len()));
            }
            Ok(Some(&rec[offset..]))
        }
        _ => Err("unknown command (EXPERT|PING)".into()),
    }
}

/// A fetched record plus how many transport retries it cost.
pub struct FetchedRecord {
    pub bytes: Vec<u8>,
    pub retries: u32,
}

/// Fetch one expert record (suffix from `offset`) from a peer.
///
/// Transient I/O errors are retried within `policy`'s bounds; a protocol
/// `ERR` reply (wrong shard, bad args) is not transient and fails
/// immediately. Every chunk of the body read is reported to `on_chunk`
/// with the wall time the read took, so the caller can charge a modeled
/// network link at chunk granularity.
pub fn fetch_record(
    addr: &str,
    key: ExpertKey,
    prec: Precision,
    offset: usize,
    expect_len: usize,
    chunk_bytes: usize,
    policy: &RetryPolicy,
    on_chunk: &mut dyn FnMut(usize, Duration),
) -> io::Result<FetchedRecord> {
    let attempts = policy.attempts.max(1);
    let mut retries = 0u32;
    let mut delay = policy.backoff;
    loop {
        match fetch_once(addr, key, prec, offset, expect_len, chunk_bytes, policy, on_chunk) {
            Ok(bytes) => return Ok(FetchedRecord { bytes, retries }),
            // ERR replies are deterministic; retrying cannot help
            Err(e) if e.kind() == io::ErrorKind::InvalidData => return Err(e),
            Err(e) => {
                if retries + 1 >= attempts {
                    return Err(e);
                }
                std::thread::sleep(delay);
                delay = delay.saturating_mul(2);
                retries += 1;
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn fetch_once(
    addr: &str,
    key: ExpertKey,
    prec: Precision,
    offset: usize,
    expect_len: usize,
    chunk_bytes: usize,
    policy: &RetryPolicy,
    on_chunk: &mut dyn FnMut(usize, Duration),
) -> io::Result<Vec<u8>> {
    let mut stream = transport::connect(addr, policy)?;
    stream.write_all(
        format!("EXPERT {} {} {} {}\n", key.layer, key.expert, prec.name(), offset).as_bytes(),
    )?;
    let mut reader = BufReader::new(&mut stream);
    let mut header = String::new();
    reader.read_line(&mut header)?;
    let header = header.trim();
    let rest = match header.strip_prefix("OK ") {
        Some(rest) => rest,
        None => {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("peer {addr}: {header}"),
            ))
        }
    };
    let mut toks = rest.split_whitespace();
    let n: usize = toks
        .next()
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidData, "bad OK header"))?;
    // frame checksum: optional for compatibility with pre-integrity peers
    let wire_sum = match toks.next() {
        Some(hex) => Some(from_hex(hex).ok_or_else(|| {
            io::Error::new(io::ErrorKind::InvalidData, "bad OK header checksum")
        })?),
        None => None,
    };
    if n != expect_len {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("peer {addr}: record length {n}, expected {expect_len}"),
        ));
    }
    let mut bytes = vec![0u8; n];
    let chunk = chunk_bytes.max(1);
    let mut read = 0usize;
    while read < n {
        let m = chunk.min(n - read);
        let t0 = Instant::now();
        reader.read_exact(&mut bytes[read..read + m])?;
        on_chunk(m, t0.elapsed());
        read += m;
    }
    if let Some(sum) = wire_sum {
        if fnv1a64(&bytes) != sum {
            // deliberately InvalidData (non-retryable): a corrupt peer is
            // failed over, not hammered — the tiered store heals from the
            // next tier down
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("peer {addr}: record checksum mismatch"),
            ));
        }
    }
    Ok(bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synth::{tiny_store_config, write_synth_expert_store};

    fn test_store(name: &str) -> Arc<ExpertStore> {
        let cfg = tiny_store_config(name);
        let dir = std::env::temp_dir().join(format!("hobbit_shard_unit_{name}"));
        write_synth_expert_store(&dir, &cfg).unwrap();
        Arc::new(ExpertStore::load(&dir, &cfg).unwrap())
    }

    #[test]
    fn expert_verb_round_trips_bytes_and_offsets() {
        let store = test_store("roundtrip");
        let key = ExpertKey::new(1, 2);
        let want = store.record(key, Precision::Q8).to_vec();
        let server =
            ShardServer::bind("127.0.0.1:0", store.clone(), ShardSpec::all(), 128).unwrap();
        let addr = server.serve_background().to_string();
        let policy = RetryPolicy::fast();
        let mut chunks = 0usize;
        let got = fetch_record(
            &addr,
            key,
            Precision::Q8,
            0,
            want.len(),
            128,
            &policy,
            &mut |_, _| chunks += 1,
        )
        .unwrap();
        assert_eq!(got.bytes, want, "remote record must be byte-identical");
        assert_eq!(got.retries, 0);
        assert!(chunks >= want.len() / 128, "body must stream in chunks");
        // offset fetch returns the suffix
        let got = fetch_record(
            &addr,
            key,
            Precision::Q8,
            100,
            want.len() - 100,
            128,
            &policy,
            &mut |_, _| {},
        )
        .unwrap();
        assert_eq!(got.bytes, want[100..]);
    }

    #[test]
    fn out_of_shard_and_bad_requests_err_without_retry() {
        let store = test_store("shardcheck");
        let shard = ShardSpec::parse("0-3").unwrap(); // layer 0 only (4 experts/layer)
        let server = ShardServer::bind("127.0.0.1:0", store.clone(), shard, 4096).unwrap();
        let addr = server.serve_background().to_string();
        let policy = RetryPolicy::fast();
        let n = store.record_bytes(Precision::Q4);
        // in shard: fine
        fetch_record(
            &addr,
            ExpertKey::new(0, 1),
            Precision::Q4,
            0,
            n,
            4096,
            &policy,
            &mut |_, _| {},
        )
        .unwrap();
        // out of shard: immediate protocol error
        let err = fetch_record(
            &addr,
            ExpertKey::new(3, 0),
            Precision::Q4,
            0,
            n,
            4096,
            &policy,
            &mut |_, _| {},
        )
        .unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("not in this shard"), "{err}");
        // out of model range
        let err = fetch_record(
            &addr,
            ExpertKey::new(9, 0),
            Precision::Q4,
            0,
            n,
            4096,
            &policy,
            &mut |_, _| {},
        )
        .unwrap_err();
        assert!(err.to_string().contains("out of model range"), "{err}");
        // PING liveness answers on the same protocol
        let reply = transport::request_line(&addr, "PING", &policy).unwrap();
        assert_eq!(reply, "OK 0");
    }

    #[test]
    fn flipped_reply_fails_the_frame_checksum_without_retry() {
        let store = test_store("peerflip");
        let plan = Arc::new(FaultPlan::parse("11:flip@peer#1").unwrap());
        let server = ShardServer::bind("127.0.0.1:0", store.clone(), ShardSpec::all(), 4096)
            .unwrap()
            .with_faults(Some(plan));
        let addr = server.serve_background().to_string();
        let policy = RetryPolicy::fast();
        let key = ExpertKey::new(0, 0);
        let n = store.record_bytes(Precision::Q8);
        // first reply is flipped after the header checksum was computed:
        // the client's post-read check catches it, non-retryably
        let err = fetch_record(&addr, key, Precision::Q8, 0, n, 4096, &policy, &mut |_, _| {})
            .unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("checksum mismatch"), "{err}");
        // the plan only fires once; the second fetch is clean
        let got = fetch_record(&addr, key, Precision::Q8, 0, n, 4096, &policy, &mut |_, _| {})
            .unwrap();
        assert_eq!(got.bytes, store.record(key, Precision::Q8));
    }

    #[test]
    fn truncated_reply_is_transient_and_retried() {
        let store = test_store("peertrunc");
        let plan = Arc::new(FaultPlan::parse("12:trunc@peer#1").unwrap());
        let server = ShardServer::bind("127.0.0.1:0", store.clone(), ShardSpec::all(), 4096)
            .unwrap()
            .with_faults(Some(plan));
        let addr = server.serve_background().to_string();
        let policy = RetryPolicy::fast();
        let key = ExpertKey::new(1, 1);
        let n = store.record_bytes(Precision::Q4);
        // first reply tears mid-record (connection drops): UnexpectedEof is
        // transient, so the retry loop re-fetches and the record lands clean
        let got = fetch_record(&addr, key, Precision::Q4, 0, n, 4096, &policy, &mut |_, _| {})
            .unwrap();
        assert_eq!(got.bytes, store.record(key, Precision::Q4));
        assert_eq!(got.retries, 1, "torn stream must cost exactly one retry");
    }
}
