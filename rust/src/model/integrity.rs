//! Per-record integrity: the checksum table every tier boundary verifies
//! against.
//!
//! One FNV-1a 64 sum per (expert, precision) record, computed over the raw
//! record bytes exactly as they sit in `experts_{tier}.bin`. The table is
//! written into the weights-dir `manifest.json` under an `"integrity"` key
//! (sums as 16-hex-digit strings — u64 does not survive JSON's f64
//! numbers) by `model::synth` and `python/compile/gen_weights.py`, and
//! recomputed from the loaded bytes by `ExpertStore::load`, so in-process
//! verification works even on bare directories with no manifest.
//!
//! Verification happens where bytes *land*, not where they are read: disk
//! and peer records verify in `remote/tiered.rs` before entering the
//! staged cache, chunked transfers verify at `CacheManager` commit (after
//! every resume/preemption has finished writing), and staged upgrades
//! verify before `commit_upgrade` copies them over a live slot. See
//! DESIGN.md §Integrity.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::config::{precision_slot, ModelConfig};
use crate::util::checksum::{fnv1a64, from_hex, to_hex};
use crate::util::json::Json;
use crate::{ExpertKey, Precision};

/// Checksums for every (expert, precision) record of one model, indexed
/// `[precision_slot][flat expert index]`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IntegrityTable {
    sums: [Vec<u64>; 4],
}

impl IntegrityTable {
    /// Compute the table from the four contiguous tier buffers (each
    /// `total_experts * record_bytes` long), indexed by precision slot.
    pub fn from_tier_buffers(cfg: &ModelConfig, tiers: [&[u8]; 4]) -> Result<Self> {
        let n = cfg.total_experts();
        let mut sums: [Vec<u64>; 4] = Default::default();
        for p in Precision::ALL {
            let slot = precision_slot(p);
            let rb = cfg.bytes_for(p);
            let buf = tiers[slot];
            anyhow::ensure!(
                buf.len() == rb * n,
                "tier {} buffer is {} bytes, expected {} records x {} bytes",
                p.name(),
                buf.len(),
                n,
                rb
            );
            sums[slot] = buf.chunks_exact(rb).map(fnv1a64).collect();
        }
        Ok(Self { sums })
    }

    /// Expected checksum of one record.
    pub fn checksum(&self, flat: usize, p: Precision) -> Option<u64> {
        self.sums[precision_slot(p)].get(flat).copied()
    }

    /// Whether `bytes` match the recorded sum for this record. Records
    /// outside the table (wrong flat index) never verify.
    pub fn verify(&self, flat: usize, p: Precision, bytes: &[u8]) -> bool {
        self.checksum(flat, p) == Some(fnv1a64(bytes))
    }

    pub fn records_per_tier(&self) -> usize {
        self.sums[0].len()
    }

    /// Render as the manifest's `"integrity"` section.
    pub fn to_json(&self) -> Json {
        let mut records = BTreeMap::new();
        for p in Precision::ALL {
            records.insert(
                p.name().to_string(),
                Json::Arr(
                    self.sums[precision_slot(p)]
                        .iter()
                        .map(|&s| Json::Str(to_hex(s)))
                        .collect(),
                ),
            );
        }
        let mut obj = BTreeMap::new();
        obj.insert("algo".to_string(), Json::Str("fnv1a64".to_string()));
        obj.insert("records".to_string(), Json::Obj(records));
        Json::Obj(obj)
    }

    /// Parse a manifest's `"integrity"` section. Typed errors on unknown
    /// algorithms, missing tiers, non-hex sums, or ragged tier lengths.
    pub fn from_json(j: &Json) -> Result<Self> {
        let algo = j
            .get("algo")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("integrity section missing 'algo'"))?;
        anyhow::ensure!(algo == "fnv1a64", "unsupported integrity algo '{algo}'");
        let records = j
            .get("records")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("integrity section missing 'records'"))?;
        let mut sums: [Vec<u64>; 4] = Default::default();
        for p in Precision::ALL {
            let tier = records
                .get(p.name())
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("integrity records missing tier '{}'", p.name()))?;
            let mut v = Vec::with_capacity(tier.len());
            for (i, ent) in tier.iter().enumerate() {
                let hex = ent
                    .as_str()
                    .ok_or_else(|| anyhow!("integrity {}[{i}]: not a string", p.name()))?;
                let sum = from_hex(hex).ok_or_else(|| {
                    anyhow!("integrity {}[{i}]: bad checksum '{hex}'", p.name())
                })?;
                v.push(sum);
            }
            sums[precision_slot(p)] = v;
        }
        let n = sums[0].len();
        anyhow::ensure!(
            sums.iter().all(|t| t.len() == n),
            "integrity tiers have ragged record counts"
        );
        Ok(Self { sums })
    }
}

/// One record's verdict from a weights-dir scan.
#[derive(Debug, Clone, Copy)]
pub struct RecordCheck {
    pub key: ExpertKey,
    pub precision: Precision,
    pub ok: bool,
}

/// Result of [`verify_weights_dir`]: per-record verdicts plus totals.
#[derive(Debug)]
pub struct VerifyReport {
    pub records: Vec<RecordCheck>,
    pub passed: usize,
    pub failed: usize,
}

impl VerifyReport {
    pub fn all_ok(&self) -> bool {
        self.failed == 0
    }
}

/// Scan a weights directory against its manifest checksums: the engine of
/// `hobbit verify-weights`. Reads `manifest.json` (which must carry an
/// `"integrity"` section), then checks every record of every
/// `experts_{tier}.bin` file against the recorded sums.
pub fn verify_weights_dir(dir: &Path) -> Result<VerifyReport> {
    let man_path = dir.join("manifest.json");
    let text = std::fs::read_to_string(&man_path)
        .with_context(|| format!("reading {}", man_path.display()))?;
    let j = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", man_path.display()))?;
    let cfg = ModelConfig::from_manifest(&j).map_err(|e| anyhow!("{}: {e}", man_path.display()))?;
    let table = IntegrityTable::from_json(
        j.get("integrity")
            .ok_or_else(|| anyhow!("{}: no 'integrity' section", man_path.display()))?,
    )?;
    anyhow::ensure!(
        table.records_per_tier() == cfg.total_experts(),
        "manifest integrity covers {} records, model has {}",
        table.records_per_tier(),
        cfg.total_experts()
    );
    let mut records = Vec::new();
    let (mut passed, mut failed) = (0usize, 0usize);
    for p in Precision::ALL {
        let path = dir.join(format!("experts_{}.bin", p.name()));
        let buf = std::fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
        let rb = cfg.bytes_for(p);
        anyhow::ensure!(
            buf.len() == rb * cfg.total_experts(),
            "{} is {} bytes, expected {}",
            path.display(),
            buf.len(),
            rb * cfg.total_experts()
        );
        for (flat, rec) in buf.chunks_exact(rb).enumerate() {
            let ok = table.verify(flat, p, rec);
            let key = ExpertKey::new(
                (flat / cfg.n_experts as usize) as u32,
                (flat % cfg.n_experts as usize) as u32,
            );
            if ok {
                passed += 1;
            } else {
                failed += 1;
            }
            records.push(RecordCheck { key, precision: p, ok });
        }
    }
    Ok(VerifyReport { records, passed, failed })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synth::{tiny_store_config, write_synth_expert_store, write_store_manifest};

    fn store_buffers(cfg: &ModelConfig) -> [Vec<u8>; 4] {
        let mut out: [Vec<u8>; 4] = Default::default();
        for p in Precision::ALL {
            let n = cfg.bytes_for(p) * cfg.total_experts();
            out[precision_slot(p)] = (0..n).map(|i| (i % 251) as u8).collect();
        }
        out
    }

    #[test]
    fn table_json_round_trips() {
        let cfg = tiny_store_config("it-rt");
        let bufs = store_buffers(&cfg);
        let t = IntegrityTable::from_tier_buffers(
            &cfg,
            [&bufs[0], &bufs[1], &bufs[2], &bufs[3]],
        )
        .unwrap();
        let j = Json::parse(&t.to_json().to_string()).unwrap();
        let back = IntegrityTable::from_json(&j).unwrap();
        assert_eq!(back, t);
        assert_eq!(back.records_per_tier(), cfg.total_experts());
    }

    #[test]
    fn verify_catches_any_single_bit_flip() {
        let cfg = tiny_store_config("it-flip");
        let bufs = store_buffers(&cfg);
        let t = IntegrityTable::from_tier_buffers(
            &cfg,
            [&bufs[0], &bufs[1], &bufs[2], &bufs[3]],
        )
        .unwrap();
        let rb = cfg.bytes_for(Precision::Q4);
        let mut rec = bufs[precision_slot(Precision::Q4)][rb * 5..rb * 6].to_vec();
        assert!(t.verify(5, Precision::Q4, &rec));
        rec[rb / 2] ^= 0x01;
        assert!(!t.verify(5, Precision::Q4, &rec));
        // out-of-table records never verify
        assert!(!t.verify(cfg.total_experts(), Precision::Q4, &rec));
    }

    #[test]
    fn from_json_rejects_malformed_sections() {
        let good = {
            let cfg = tiny_store_config("it-bad");
            let bufs = store_buffers(&cfg);
            IntegrityTable::from_tier_buffers(&cfg, [&bufs[0], &bufs[1], &bufs[2], &bufs[3]])
                .unwrap()
                .to_json()
                .to_string()
        };
        for (mangle, why) in [
            (good.replace("fnv1a64", "crc32"), "unknown algo"),
            (good.replace("\"q2\"", "\"qx\""), "missing tier"),
            (good.replacen("\"records\"", "\"wrong\"", 1), "missing records"),
        ] {
            let j = Json::parse(&mangle).unwrap();
            assert!(IntegrityTable::from_json(&j).is_err(), "{why} should fail");
        }
    }

    #[test]
    fn weights_dir_scan_reports_a_flipped_byte() {
        let dir = std::env::temp_dir().join("hobbit_it_scan");
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = tiny_store_config("it-scan");
        write_synth_expert_store(&dir, &cfg).unwrap();
        write_store_manifest(&dir, &cfg).unwrap();
        let rep = verify_weights_dir(&dir).unwrap();
        assert!(rep.all_ok());
        assert_eq!(rep.passed, cfg.total_experts() * 4);

        // flip one byte of one q8 record on disk
        let path = dir.join("experts_q8.bin");
        let mut bytes = std::fs::read(&path).unwrap();
        let rb = cfg.bytes_for(Precision::Q8);
        bytes[rb * 3 + 7] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let rep = verify_weights_dir(&dir).unwrap();
        assert_eq!(rep.failed, 1);
        let bad: Vec<_> = rep.records.iter().filter(|r| !r.ok).collect();
        assert_eq!(bad.len(), 1);
        assert_eq!(bad[0].precision, Precision::Q8);
        assert_eq!(bad[0].key.index(cfg.n_experts), 3);
    }
}
