//! Model weights: the non-expert weights (always resident, Fig 2) and the
//! expert store (the "next-level memory" tier holding every expert at
//! every precision, exported by `python/compile/gen_weights.py`).

pub mod integrity;
pub mod synth;
mod weights;

pub use integrity::{verify_weights_dir, IntegrityTable, VerifyReport};
pub use weights::{ExpertStore, NonExpertWeights};

use anyhow::Result;
use xla::Literal;

use crate::config::ModelConfig;
use crate::runtime::{lit_f32, lit_u8};
use crate::Precision;

/// Slice an expert record (the raw bytes the loader moved into cache) into
/// the literal arguments the `expert_{fmt}_s{S}` artifact expects:
/// f32 -> [w1, w3, w2]; quantized -> [w1p, w1s, w3p, w3s, w2p, w2s].
pub fn expert_literals(cfg: &ModelConfig, p: Precision, record: &[u8]) -> Result<Vec<Literal>> {
    let d = cfg.d_model;
    let ff = cfg.d_ff;
    let g = cfg.quant_group;
    let mut out = Vec::new();
    match p {
        Precision::F32 => {
            let floats: &[f32] = cast_f32(record);
            let (n1, n2) = (d * ff, ff * d);
            anyhow::ensure!(floats.len() == 2 * n1 + n2, "f32 record size mismatch");
            out.push(lit_f32(&[d, ff], &floats[..n1])?);
            out.push(lit_f32(&[d, ff], &floats[n1..2 * n1])?);
            out.push(lit_f32(&[ff, d], &floats[2 * n1..])?);
        }
        _ => {
            let pack = p.pack();
            let mut off = 0usize;
            for (rows, cols) in [(d, ff), (d, ff), (ff, d)] {
                let nb = rows / pack * cols;
                out.push(lit_u8(&[rows / pack, cols], &record[off..off + nb])?);
                off += nb;
                let ns = rows / g * cols * 4;
                out.push(lit_f32(&[rows / g, cols], cast_f32(&record[off..off + ns]))?);
                off += ns;
            }
            anyhow::ensure!(off == record.len(), "quant record size mismatch");
        }
    }
    Ok(out)
}

/// Reinterpret little-endian bytes as f32s (alignment-safe copy fallback).
fn cast_f32(bytes: &[u8]) -> &[f32] {
    assert_eq!(bytes.len() % 4, 0);
    assert_eq!(bytes.as_ptr() as usize % 4, 0, "unaligned f32 view");
    unsafe { std::slice::from_raw_parts(bytes.as_ptr() as *const f32, bytes.len() / 4) }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            n_layers: 2,
            d_model: 64,
            d_ff: 128,
            n_experts: 4,
            top_k: 2,
            n_heads: 4,
            n_kv_heads: 2,
            vocab: 260,
            max_seq: 32,
            quant_group: 32,
            expert_bytes: [0; 4],
        }
    }

    #[test]
    fn f32_record_slicing() {
        let cfg = tiny_cfg();
        let n = 2 * cfg.d_model * cfg.d_ff + cfg.d_ff * cfg.d_model;
        let floats: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let bytes: Vec<u8> =
            floats.iter().flat_map(|f| f.to_le_bytes()).collect();
        let lits = expert_literals(&cfg, Precision::F32, &bytes).unwrap();
        assert_eq!(lits.len(), 3);
        assert_eq!(lits[0].element_count(), cfg.d_model * cfg.d_ff);
        assert_eq!(lits[2].to_vec::<f32>().unwrap()[0], (2 * cfg.d_model * cfg.d_ff) as f32);
    }

    #[test]
    fn quant_record_slicing() {
        let cfg = tiny_cfg();
        let (d, ff, g) = (cfg.d_model, cfg.d_ff, cfg.quant_group);
        for p in [Precision::Q8, Precision::Q4, Precision::Q2] {
            let pk = p.pack();
            let rec_len = (d / pk * ff + d / g * ff * 4) * 2 + ff / pk * d + ff / g * d * 4;
            let rec = vec![0u8; rec_len];
            let lits = expert_literals(&cfg, p, &rec).unwrap();
            assert_eq!(lits.len(), 6, "{p:?}");
        }
    }

    #[test]
    fn bad_record_size_rejected() {
        let cfg = tiny_cfg();
        assert!(expert_literals(&cfg, Precision::F32, &[0u8; 16]).is_err());
    }
}
