//! Weight files: non-expert weights (resident in "GPU memory") and the
//! expert store ("next-level memory": CPU RAM standing in for CPU/SSD,
//! with transfer costs modeled by `memory::TransferEngine`).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::ModelConfig;
use crate::model::integrity::IntegrityTable;
use crate::util::json::Json;
use crate::{ExpertKey, Precision};

/// All non-expert tensors, loaded once and kept resident (they are 4% of
/// the model, Fig 2-b).
pub struct NonExpertWeights {
    data: Vec<f32>,
    index: HashMap<String, (Vec<usize>, usize)>, // name -> (shape, f32 offset)
}

impl NonExpertWeights {
    pub fn load(weights_dir: &Path) -> Result<Self> {
        let man_path = weights_dir.join("weights.json");
        let text = std::fs::read_to_string(&man_path)
            .with_context(|| format!("reading {}", man_path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("weights.json: {e}"))?;
        let bytes = std::fs::read(weights_dir.join("nonexpert.bin"))?;
        anyhow::ensure!(bytes.len() % 4 == 0);
        let mut data = vec![0f32; bytes.len() / 4];
        // copy to guarantee alignment
        unsafe {
            std::ptr::copy_nonoverlapping(
                bytes.as_ptr(),
                data.as_mut_ptr() as *mut u8,
                bytes.len(),
            );
        }
        let mut index = HashMap::new();
        for ent in j.get("nonexpert").and_then(Json::as_arr).unwrap_or(&[]) {
            let name = ent.get("name").and_then(Json::as_str).ok_or(anyhow!("bad entry"))?;
            let shape: Vec<usize> = ent
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or(anyhow!("bad shape"))?
                .iter()
                .filter_map(Json::as_usize)
                .collect();
            let offset = ent.get("offset").and_then(Json::as_usize).ok_or(anyhow!("bad offset"))?;
            anyhow::ensure!(offset % 4 == 0);
            index.insert(name.to_string(), (shape, offset / 4));
        }
        Ok(Self { data, index })
    }

    /// Tensor view by name (e.g. "wq.3", "emb").
    pub fn get(&self, name: &str) -> Result<(&[usize], &[f32])> {
        let (shape, off) = self
            .index
            .get(name)
            .ok_or_else(|| anyhow!("no non-expert tensor '{name}'"))?;
        let n: usize = shape.iter().product();
        if off + n > self.data.len() {
            bail!("tensor '{name}' out of range");
        }
        Ok((shape, &self.data[*off..*off + n]))
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.index.keys()
    }
}

/// Every expert at every precision, resident in host memory as the
/// "next-level memory" tier. Records are 4-byte aligned so f32 views are
/// valid (we own the buffers via Vec<f32> backing).
pub struct ExpertStore {
    cfg: ModelConfig,
    /// per precision slot: backing buffer (f32-aligned) and record stride
    tiers: [Tier; 4],
    /// per-record checksums computed from the loaded bytes — the reference
    /// every downstream tier crossing (peer, staged, commit) verifies
    /// against. When the directory carries a manifest integrity section,
    /// load itself verifies against it, so a record that rotted on disk
    /// before this process started is caught here.
    integrity: IntegrityTable,
}

struct Tier {
    buf: Vec<u8>,
    record_bytes: usize,
}

fn read_aligned(path: &Path) -> Result<Vec<u8>> {
    let raw = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    // Vec<u8> from fs::read is not guaranteed 4-aligned; re-allocate via
    // Vec<u32> to force alignment, then transmute the storage.
    let words = (raw.len() + 3) / 4;
    let mut v32 = vec![0u32; words];
    unsafe {
        std::ptr::copy_nonoverlapping(raw.as_ptr(), v32.as_mut_ptr() as *mut u8, raw.len());
        let ptr = v32.as_mut_ptr() as *mut u8;
        let cap = v32.capacity() * 4;
        std::mem::forget(v32);
        Ok(Vec::from_raw_parts(ptr, raw.len(), cap))
    }
}

impl ExpertStore {
    pub fn load(weights_dir: &Path, cfg: &ModelConfig) -> Result<Self> {
        let mut tiers = Vec::new();
        for p in Precision::ALL {
            let path = weights_dir.join(format!("experts_{}.bin", p.name()));
            let buf = read_aligned(&path)?;
            let record_bytes = cfg.bytes_for(p);
            anyhow::ensure!(
                buf.len() == record_bytes * cfg.total_experts(),
                "expert file {} size mismatch: {} != {} * {}",
                path.display(),
                buf.len(),
                record_bytes,
                cfg.total_experts()
            );
            tiers.push(Tier { buf, record_bytes });
        }
        let tiers: [Tier; 4] = tiers.try_into().map_err(|_| anyhow!("tier count"))?;
        let integrity = IntegrityTable::from_tier_buffers(
            cfg,
            [&tiers[0].buf, &tiers[1].buf, &tiers[2].buf, &tiers[3].buf],
        )?;
        let store = Self { cfg: cfg.clone(), tiers, integrity };
        store.verify_against_manifest(weights_dir)?;
        Ok(store)
    }

    /// If the directory carries a manifest with an integrity section,
    /// check the loaded bytes against it; a mismatch is a typed error
    /// naming the first rotten record. Directories without a manifest (or
    /// with a manifest predating the integrity layer) load unverified —
    /// the store's own computed table still guards every later tier hop.
    fn verify_against_manifest(&self, weights_dir: &Path) -> Result<()> {
        let man_path = weights_dir.join("manifest.json");
        let text = match std::fs::read_to_string(&man_path) {
            Ok(t) => t,
            Err(_) => return Ok(()),
        };
        let j = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", man_path.display()))?;
        let Some(sec) = j.get("integrity") else { return Ok(()) };
        let expected = IntegrityTable::from_json(sec)
            .with_context(|| format!("{}: bad integrity section", man_path.display()))?;
        anyhow::ensure!(
            expected.records_per_tier() == self.cfg.total_experts(),
            "{}: integrity covers {} records, model has {}",
            man_path.display(),
            expected.records_per_tier(),
            self.cfg.total_experts()
        );
        for p in Precision::ALL {
            for flat in 0..self.cfg.total_experts() {
                if expected.checksum(flat, p) != self.integrity.checksum(flat, p) {
                    let key = ExpertKey::new(
                        (flat / self.cfg.n_experts as usize) as u32,
                        (flat % self.cfg.n_experts as usize) as u32,
                    );
                    bail!(
                        "expert record corrupt on disk: layer {} expert {} tier {} \
                         fails its manifest checksum",
                        key.layer,
                        key.expert,
                        p.name()
                    );
                }
            }
        }
        Ok(())
    }

    /// The per-record checksum table (computed from the loaded bytes).
    pub fn integrity(&self) -> &IntegrityTable {
        &self.integrity
    }

    /// Raw record bytes of one expert at one precision.
    pub fn record(&self, key: ExpertKey, p: Precision) -> &[u8] {
        let tier = &self.tiers[crate::config::precision_slot(p)];
        let idx = key.index(self.cfg.n_experts);
        &tier.buf[idx * tier.record_bytes..(idx + 1) * tier.record_bytes]
    }

    pub fn record_bytes(&self, p: Precision) -> usize {
        self.tiers[crate::config::precision_slot(p)].record_bytes
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_aligned_is_aligned() {
        let dir = std::env::temp_dir().join("hobbit_test_align");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.bin");
        std::fs::write(&p, [1u8, 2, 3, 4, 5]).unwrap();
        let v = read_aligned(&p).unwrap();
        assert_eq!(v, vec![1, 2, 3, 4, 5]);
        assert_eq!(v.as_ptr() as usize % 4, 0);
    }
}
