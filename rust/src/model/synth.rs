//! Synthesized model weights: writes a complete on-disk weight directory
//! (`weights.json` + `nonexpert.bin` + `experts_{f32,q8,q4,q2}.bin`) for a
//! tiny random-but-deterministic model, byte-compatible with the formats
//! `python/compile/gen_weights.py` exports.
//!
//! This is what makes the batched-decode regression suite artifact-free:
//! `Engine::new_reference` + a synthesized directory drive the *real*
//! loader/cache/predictor/scheduler stack — only the AOT compile step is
//! bypassed. The quantized tiers are packed with `quant::quantize`, so the
//! mixed-precision paths (records, scales, dequant) are real too.

use std::path::Path;

use anyhow::{Context, Result};

use crate::config::ModelConfig;
use crate::model::integrity::IntegrityTable;
use crate::quant;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::Precision;

/// A tiny model shape for the artifact-free suites. The vocab matches the
/// byte tokenizer (`tokenizer::VOCAB`) so the serving path is end-to-end
/// real; `expert_bytes` is derived from the layout below.
pub fn tiny_model_config(name: &str) -> ModelConfig {
    let (d, ff, g) = (16usize, 32usize, 16usize);
    let mut cfg = ModelConfig {
        name: name.into(),
        n_layers: 3,
        d_model: d,
        d_ff: ff,
        n_experts: 4,
        top_k: 2,
        n_heads: 2,
        n_kv_heads: 1,
        vocab: crate::tokenizer::VOCAB,
        max_seq: 64,
        quant_group: g,
        expert_bytes: [0; 4],
    };
    for p in Precision::ALL {
        cfg.expert_bytes[crate::config::precision_slot(p)] = expert_record_bytes(&cfg, p);
    }
    cfg
}

/// On-wire record size of one expert at one precision under the
/// `[w1, w3, w2]` (f32) / `[w1p, w1s, w3p, w3s, w2p, w2s]` (quant) layout
/// that `model::expert_literals` slices.
pub fn expert_record_bytes(cfg: &ModelConfig, p: Precision) -> usize {
    let (d, ff, g) = (cfg.d_model, cfg.d_ff, cfg.quant_group);
    match p {
        Precision::F32 => (2 * d * ff + ff * d) * 4,
        _ => [(d, ff), (d, ff), (ff, d)]
            .iter()
            .map(|&(rows, cols)| {
                quant::packed_bytes(rows, cols, p) + quant::scale_count(rows, cols, g) * 4
            })
            .sum(),
    }
}

fn push_f32(buf: &mut Vec<u8>, data: &[f32]) {
    for v in data {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

/// Deterministic random weights with magnitudes that keep softmax gates
/// and logits well-conditioned (roughly orthogonal-init scale).
fn rand_mat(rng: &mut Rng, rows: usize, cols: usize, scale: f64) -> Vec<f32> {
    (0..rows * cols).map(|_| (rng.normal() * scale) as f32).collect()
}

/// A tiny *store-only* shape for the loader/transfer-pipeline suites:
/// synthetic on-wire record sizes, no attention dims ever exercised —
/// only consistency with [`write_synth_expert_store`] matters. (The
/// residency suite predates this helper and carries its own copy.)
pub fn tiny_store_config(name: &str) -> ModelConfig {
    ModelConfig {
        name: name.into(),
        n_layers: 4,
        d_model: 8,
        d_ff: 16,
        n_experts: 4,
        top_k: 2,
        n_heads: 2,
        n_kv_heads: 1,
        vocab: 64,
        max_seq: 32,
        quant_group: 8,
        expert_bytes: [4096, 1024, 512, 256],
    }
}

/// Write only the per-precision expert record files (`experts_*.bin`) —
/// enough for `ExpertStore::load` to move real bytes, not for engine
/// construction (use [`write_synth_model`] for that). Deterministic byte
/// pattern, so suites can compare transferred bytes against the store.
pub fn write_synth_expert_store(dir: &Path, cfg: &ModelConfig) -> Result<()> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating {}", dir.display()))?;
    for p in Precision::ALL {
        let n = cfg.bytes_for(p) * cfg.total_experts();
        let bytes: Vec<u8> = (0..n).map(|i| (i % 251) as u8).collect();
        std::fs::write(dir.join(format!("experts_{}.bin", p.name())), bytes)
            .with_context(|| format!("writing experts_{}.bin", p.name()))?;
    }
    Ok(())
}

/// Write `manifest.json` next to the weight files so a shard server can
/// recover the model shape from the directory alone (`hobbit shard-serve`
/// reads it back through `ModelConfig::from_manifest`). When the
/// `experts_*.bin` files are already present (the normal call order), the
/// manifest also carries the per-record `"integrity"` checksum table that
/// `verify-weights` and `ExpertStore::load` check against.
pub fn write_store_manifest(dir: &Path, cfg: &ModelConfig) -> Result<()> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating {}", dir.display()))?;
    let bufs: Option<Vec<Vec<u8>>> = Precision::ALL
        .iter()
        .map(|p| std::fs::read(dir.join(format!("experts_{}.bin", p.name()))).ok())
        .collect();
    let mut manifest = cfg.to_manifest_json();
    if let Some(bufs) = bufs {
        let table = IntegrityTable::from_tier_buffers(
            cfg,
            [&bufs[0], &bufs[1], &bufs[2], &bufs[3]],
        )?;
        if let Json::Obj(m) = &mut manifest {
            m.insert("integrity".to_string(), table.to_json());
        }
    }
    std::fs::write(dir.join("manifest.json"), manifest.to_string())
        .with_context(|| format!("writing {}/manifest.json", dir.display()))?;
    Ok(())
}

/// Write the whole synthesized model (non-expert weights + every expert
/// at every precision) under `dir`. Deterministic in `seed`.
pub fn write_synth_model(dir: &Path, cfg: &ModelConfig, seed: u64) -> Result<()> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating {}", dir.display()))?;
    let d = cfg.d_model;
    let ff = cfg.d_ff;
    let e = cfg.n_experts as usize;
    let l = cfg.n_layers as usize;
    let (h, hkv, hd) = (cfg.n_heads, cfg.n_kv_heads, cfg.head_dim());
    let mut rng = Rng::new(seed);
    let wscale = 1.0 / (d as f64).sqrt();

    // ---- non-expert weights -------------------------------------------
    let mut bin: Vec<u8> = Vec::new();
    let mut entries: Vec<Json> = Vec::new();
    let mut put = |name: String, shape: Vec<usize>, data: &[f32], bin: &mut Vec<u8>| {
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("name".to_string(), Json::Str(name));
        obj.insert(
            "shape".to_string(),
            Json::Arr(shape.iter().map(|&s| Json::Num(s as f64)).collect()),
        );
        obj.insert("offset".to_string(), Json::Num(bin.len() as f64));
        entries.push(Json::Obj(obj));
        push_f32(bin, data);
    };

    let emb = rand_mat(&mut rng, cfg.vocab, d, wscale);
    put("emb".into(), vec![cfg.vocab, d], &emb, &mut bin);
    let final_norm: Vec<f32> = (0..d).map(|_| 1.0 + rng.normal() as f32 * 0.02).collect();
    put("final_norm".into(), vec![d], &final_norm, &mut bin);
    for li in 0..l {
        let norm: Vec<f32> = (0..d).map(|_| 1.0 + rng.normal() as f32 * 0.02).collect();
        put(format!("attn_norm.{li}"), vec![d], &norm, &mut bin);
        let wq = rand_mat(&mut rng, d, h * hd, wscale);
        put(format!("wq.{li}"), vec![d, h * hd], &wq, &mut bin);
        let wk = rand_mat(&mut rng, d, hkv * hd, wscale);
        put(format!("wk.{li}"), vec![d, hkv * hd], &wk, &mut bin);
        let wv = rand_mat(&mut rng, d, hkv * hd, wscale);
        put(format!("wv.{li}"), vec![d, hkv * hd], &wv, &mut bin);
        let wo = rand_mat(&mut rng, h * hd, d, wscale);
        put(format!("wo.{li}"), vec![h * hd, d], &wo, &mut bin);
        let pn: Vec<f32> = (0..d).map(|_| 1.0 + rng.normal() as f32 * 0.02).collect();
        put(format!("post_norm.{li}"), vec![d], &pn, &mut bin);
        // gate spread wide enough that routing differs across tokens
        let wg = rand_mat(&mut rng, d, e, wscale * 2.0);
        put(format!("wg.{li}"), vec![d, e], &wg, &mut bin);
    }
    let mut manifest = std::collections::BTreeMap::new();
    manifest.insert("nonexpert".to_string(), Json::Arr(entries));
    std::fs::write(dir.join("weights.json"), Json::Obj(manifest).to_string())?;
    std::fs::write(dir.join("nonexpert.bin"), &bin)?;

    // ---- expert store (every precision) -------------------------------
    let g = cfg.quant_group;
    let mut tiers: [Vec<u8>; 4] = [Vec::new(), Vec::new(), Vec::new(), Vec::new()];
    for _li in 0..l {
        for _ei in 0..e {
            let w1 = rand_mat(&mut rng, d, ff, wscale);
            let w3 = rand_mat(&mut rng, d, ff, wscale);
            let w2 = rand_mat(&mut rng, ff, d, 1.0 / (ff as f64).sqrt());
            for p in Precision::ALL {
                let tier = &mut tiers[crate::config::precision_slot(p)];
                match p {
                    Precision::F32 => {
                        push_f32(tier, &w1);
                        push_f32(tier, &w3);
                        push_f32(tier, &w2);
                    }
                    _ => {
                        for (w, rows, cols) in
                            [(&w1, d, ff), (&w3, d, ff), (&w2, ff, d)]
                        {
                            let (packed, scales) = quant::quantize(w, rows, cols, g, p);
                            tier.extend_from_slice(&packed);
                            push_f32(tier, &scales);
                        }
                    }
                }
            }
        }
    }
    for p in Precision::ALL {
        let tier = &tiers[crate::config::precision_slot(p)];
        debug_assert_eq!(tier.len(), cfg.bytes_for(p) * cfg.total_experts());
        std::fs::write(dir.join(format!("experts_{}.bin", p.name())), tier)?;
    }

    // ---- manifest (shape + per-record integrity checksums) ------------
    let table =
        IntegrityTable::from_tier_buffers(cfg, [&tiers[0], &tiers[1], &tiers[2], &tiers[3]])?;
    let mut manifest = cfg.to_manifest_json();
    if let Json::Obj(m) = &mut manifest {
        m.insert("integrity".to_string(), table.to_json());
    }
    std::fs::write(dir.join("manifest.json"), manifest.to_string())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ExpertStore, NonExpertWeights};

    #[test]
    fn synth_model_roundtrips_through_the_real_loaders() {
        let cfg = tiny_model_config("synth-roundtrip");
        let dir = std::env::temp_dir().join("hobbit_synth_roundtrip");
        write_synth_model(&dir, &cfg, 42).unwrap();
        let ne = NonExpertWeights::load(&dir).unwrap();
        let (shape, emb) = ne.get("emb").unwrap();
        assert_eq!(shape, &[cfg.vocab, cfg.d_model][..]);
        assert!(emb.iter().all(|v| v.is_finite()));
        let (shape, _) = ne.get("wg.2").unwrap();
        assert_eq!(shape, &[cfg.d_model, cfg.n_experts as usize][..]);
        let store = ExpertStore::load(&dir, &cfg).unwrap();
        for p in Precision::ALL {
            let rec = store.record(crate::ExpertKey::new(2, 3), p);
            assert_eq!(rec.len(), cfg.bytes_for(p));
        }
    }

    #[test]
    fn record_bytes_match_quant_layout() {
        let cfg = tiny_model_config("synth-bytes");
        // f32: three matrices of floats
        let (d, ff) = (cfg.d_model, cfg.d_ff);
        assert_eq!(cfg.bytes_for(Precision::F32), (2 * d * ff + ff * d) * 4);
        // quantized tiers shrink monotonically
        assert!(cfg.bytes_for(Precision::Q8) > cfg.bytes_for(Precision::Q4));
        assert!(cfg.bytes_for(Precision::Q4) > cfg.bytes_for(Precision::Q2));
    }

    #[test]
    fn synth_is_deterministic_in_seed() {
        let cfg = tiny_model_config("synth-det");
        let d1 = std::env::temp_dir().join("hobbit_synth_det1");
        let d2 = std::env::temp_dir().join("hobbit_synth_det2");
        write_synth_model(&d1, &cfg, 7).unwrap();
        write_synth_model(&d2, &cfg, 7).unwrap();
        let a = std::fs::read(d1.join("experts_f32.bin")).unwrap();
        let b = std::fs::read(d2.join("experts_f32.bin")).unwrap();
        assert_eq!(a, b);
    }
}
