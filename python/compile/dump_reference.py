"""Dump reference logits for the cross-language correctness check.

Runs the pure-JAX oracle (model.reference_forward) on a fixed token
sequence with the exported weights and writes the logits to
artifacts/weights/<model>/reference_logits.json. The rust integration
test rust/tests/engine_vs_reference.rs replays the same tokens through
the PJRT engine and asserts agreement — the end-to-end proof that the
three layers compose.
"""

import argparse
import json

import jax.numpy as jnp
import numpy as np

from . import gen_weights, model
from .configs import MODELS

# fixed pseudo-text tokens (BOS + printable bytes), same generator as the
# rust side's figures/real.rs eval_tokens
def eval_tokens(n: int):
    v = [256]  # BOS
    s = 0x9E3779B97F4A7C15
    while len(v) < n:
        s = (s * 6364136223846793005 + 1442695040888963407) % (1 << 64)
        v.append(32 + (s >> 33) % 90)
    return v


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", nargs="*", default=list(MODELS))
    ap.add_argument("--seed", type=int, default=20240917)
    ap.add_argument("--tokens", type=int, default=24)
    args = ap.parse_args()

    for mname in args.models:
        cfg = MODELS[mname]
        params = {k: jnp.asarray(v)
                  for k, v in gen_weights.make_params(cfg, args.seed).items()}
        toks = eval_tokens(args.tokens)
        logits = model.reference_forward(cfg, params, jnp.asarray(toks, jnp.int32))
        logits = np.asarray(logits, dtype=np.float64)
        out = {
            "tokens": toks,
            "vocab": cfg.vocab,
            # logits at every position (next-token distribution per prefix)
            "logits": [[round(float(x), 6) for x in row] for row in logits],
        }
        path = f"{args.out}/weights/{mname}/reference_logits.json"
        with open(path, "w") as f:
            json.dump(out, f)
        print(f"  [{mname}] wrote reference logits for {len(toks)} tokens -> {path}")


if __name__ == "__main__":
    main()
