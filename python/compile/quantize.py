"""Symmetric group quantization for expert weights (q8 / q4 / q2).

The rust side (rust/src/quant.rs) implements byte-identical packing so that
the expert storage written by gen_weights.py can be consumed (and verified)
by the coordinator.  Layout contract, for a weight matrix W[rows, cols]
quantized along the *row* (contraction) axis with group size G:

  scales  f32[rows/G, cols]      scale of each (group, col) cell
  q8      int8 stored as u8 (two's complement) [rows, cols]
  q4      u8[rows/2, cols]; element (r, c) is the nibble
          (packed[r//2, c] >> (4*(r%2))) & 0xF, value = nibble - 8
  q2      u8[rows/4, cols]; element (r, c) is the 2-bit field
          (packed[r//4, c] >> (2*(r%4))) & 0x3, value = field - 2

All arrays are C-contiguous and written little-endian.
"""

import numpy as np

QBITS = {"q8": 8, "q4": 4, "q2": 2}
# max representable magnitude of the signed code for each format
QMAX = {"q8": 127.0, "q4": 7.0, "q2": 1.5}
# offset added when packing sub-byte codes into unsigned fields
QOFFSET = {"q4": 8, "q2": 2}


def group_scales(w: np.ndarray, group: int, fmt: str) -> np.ndarray:
    """Per-(group, col) scales so that max|w| in the group maps to QMAX."""
    rows, cols = w.shape
    assert rows % group == 0, (rows, group)
    g = w.reshape(rows // group, group, cols)
    amax = np.abs(g).max(axis=1)  # [rows/G, cols]
    scale = amax / QMAX[fmt]
    # avoid div-by-zero for all-zero groups
    return np.where(scale == 0.0, 1.0, scale).astype(np.float32)


def _codes(w: np.ndarray, scales: np.ndarray, group: int, fmt: str) -> np.ndarray:
    """Signed integer codes (float array holding integral values for q2)."""
    rows, cols = w.shape
    s = np.repeat(scales, group, axis=0)  # [rows, cols]
    q = w / s
    if fmt == "q2":
        # 4 symmetric levels {-1.5, -0.5, 0.5, 1.5}: code in {-2..1} encodes
        # level (code + 0.5). round(q - 0.5) picks the nearest level.
        c = np.clip(np.round(q - 0.5), -2, 1)
    else:
        c = np.clip(np.round(q), -QMAX[fmt], QMAX[fmt])
    return c


def quantize(w: np.ndarray, group: int, fmt: str):
    """Quantize f32 W[rows, cols] -> (packed u8 array, scales f32).

    Returns (packed, scales) per the module-level layout contract.
    """
    assert w.ndim == 2 and w.dtype == np.float32
    scales = group_scales(w, group, fmt)
    c = _codes(w, scales, group, fmt)
    rows, cols = w.shape
    if fmt == "q8":
        packed = c.astype(np.int8).view(np.uint8)
    elif fmt == "q4":
        u = (c.astype(np.int32) + QOFFSET["q4"]).astype(np.uint8)  # 0..15
        lo = u[0::2, :]
        hi = u[1::2, :]
        packed = (lo | (hi << 4)).astype(np.uint8)
    elif fmt == "q2":
        u = (c.astype(np.int32) + QOFFSET["q2"]).astype(np.uint8)  # 0..3
        packed = np.zeros((rows // 4, cols), dtype=np.uint8)
        for i in range(4):
            packed |= u[i::4, :] << (2 * i)
    else:
        raise ValueError(fmt)
    return np.ascontiguousarray(packed), np.ascontiguousarray(scales)


def unpack_codes(packed: np.ndarray, rows: int, fmt: str) -> np.ndarray:
    """Inverse of the packing step: u8 packed -> float signed codes [rows, cols]."""
    if fmt == "q8":
        return packed.view(np.int8).astype(np.float32)
    if fmt == "q4":
        cols = packed.shape[1]
        out = np.empty((rows, cols), dtype=np.float32)
        out[0::2, :] = (packed & 0xF).astype(np.float32) - QOFFSET["q4"]
        out[1::2, :] = (packed >> 4).astype(np.float32) - QOFFSET["q4"]
        return out
    if fmt == "q2":
        cols = packed.shape[1]
        out = np.empty((rows, cols), dtype=np.float32)
        for i in range(4):
            out[i::4, :] = ((packed >> (2 * i)) & 0x3).astype(np.float32) - QOFFSET["q2"]
        return out
    raise ValueError(fmt)


def dequantize(packed: np.ndarray, scales: np.ndarray, rows: int, group: int, fmt: str) -> np.ndarray:
    """Reconstruct f32 weights from packed codes + scales."""
    c = unpack_codes(packed, rows, fmt)
    if fmt == "q2":
        c = c + 0.5  # levels are code + 0.5 (see _codes)
    s = np.repeat(scales, group, axis=0)
    return (c * s).astype(np.float32)


def quantize_roundtrip(w: np.ndarray, group: int, fmt: str) -> np.ndarray:
    """Quantize then dequantize — what the model actually computes with."""
    packed, scales = quantize(w, group, fmt)
    return dequantize(packed, scales, w.shape[0], group, fmt)
