"""L2: the MoE transformer compute graph in JAX, calling the L1 kernels.

This module defines every function the rust coordinator executes at
runtime; each one is AOT-lowered to HLO text by aot.py against a concrete
(model, sequence-length) shape and never re-traced after build time.

Granularity follows the paper's execution model: the coordinator owns the
layer loop and the expert-cache state, so the compiled units are

  attn_block   — RMSNorm + RoPE GQA attention + residual, with the KV cache
                 threaded through functionally (read in, updated copies out)
  gate_stack   — the Stacking Computer (§3.3): softmax gating of the current
                 hidden state against the next p layers' gate matrices
  expert_ffn   — one expert's weighted SwiGLU FFN at a given precision
                 (f32 / q8 / q4 / q2), pallas kernel inside
  lm_head      — final RMSNorm + tied-embedding logits

The coordinator composes these per token/layer, deciding *which* expert
weights (and at what precision) to feed expert_ffn — that choice is the
paper's contribution and lives in rust (L3).
"""

import jax
import jax.numpy as jnp

from .kernels import moe_ffn, gating


def rmsnorm(x, w, eps):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope(q, pos, theta):
    """Rotary embedding. q: [S, H, hd]; pos: scalar start position."""
    s, _, hd = q.shape
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    t = (pos + jnp.arange(s, dtype=jnp.float32))[:, None] * freqs[None, :]
    cos, sin = jnp.cos(t), jnp.sin(t)          # [S, half]
    q1, q2 = q[..., :half], q[..., half:]
    cos, sin = cos[:, None, :], sin[:, None, :]
    return jnp.concatenate([q1 * cos - q2 * sin, q1 * sin + q2 * cos], axis=-1)


def attn_block(cfg, x, norm_w, wq, wk, wv, wo, kcache, vcache, pos):
    """Attention sub-block with functional KV cache.

    x: [S, d]; wq: [d, H*hd]; wk, wv: [d, Hkv*hd]; wo: [H*hd, d]
    kcache, vcache: [T, Hkv, hd]; pos: s32 scalar (write offset)
    returns (x + attn_out [S, d], kcache', vcache')

    Rows of a partially-filled chunk beyond the true prompt length write
    garbage cache slots ≥ pos+len; the coordinator overwrites them on the
    next chunk and the causal/length mask keeps them invisible meanwhile.
    """
    s, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    t = kcache.shape[0]
    posf = pos.astype(jnp.float32)
    hn = rmsnorm(x, norm_w, cfg.norm_eps)
    q = (hn @ wq).reshape(s, h, hd)
    k = (hn @ wk).reshape(s, hkv, hd)
    v = (hn @ wv).reshape(s, hkv, hd)
    q = rope(q, posf, cfg.rope_theta)
    k = rope(k, posf, cfg.rope_theta)

    kcache = jax.lax.dynamic_update_slice(kcache, k, (pos, 0, 0))
    vcache = jax.lax.dynamic_update_slice(vcache, v, (pos, 0, 0))

    # GQA without materializing repeated KV heads (§Perf: the jnp.repeat
    # version copied the whole cache twice per call): group query heads by
    # their kv head and contract against the cache directly.
    rep = h // hkv
    qg = q.reshape(s, hkv, rep, hd)
    scores = jnp.einsum("sgrd,tgd->grst", qg, kcache) / jnp.sqrt(float(hd))
    # causal + length mask: query row i (absolute pos+i) sees keys j <= pos+i
    j = jnp.arange(t)[None, :]                  # [1, T]
    i = pos + jnp.arange(s)[:, None]            # [S, 1]
    mask = (j <= i)[None, None, :, :]           # [1, 1, S, T]
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("grst,tgd->sgrd", probs, vcache).reshape(s, h * hd)
    return x + out @ wo, kcache, vcache


def gate_stack(cfg, x, post_norm_w, wg_stack):
    """The Stacking Computer (§3.3). x: [S, d] is the attention-block
    output of the current layer; post_norm_w: [p, d] are the stacked
    layers' post-attention norm weights; wg_stack: [p, d, E].
    Returns gating probs [p, S, E].

    Index 0 is the *current* layer (its probs drive on-demand selection);
    indices 1..p-1 are the predictions for subsequent layers (Fig 8) —
    they reuse the current hidden state, exploiting the residual-stream
    similarity the paper measures in Fig 7.
    """
    p = wg_stack.shape[0]
    xs = jnp.stack([rmsnorm(x, post_norm_w[i], cfg.norm_eps) for i in range(p)])
    return gating.gate_stack(xs, wg_stack)


def gate_sequential(cfg, x, post_norm_w, wg_stack):
    """Naive per-layer gating loop — the baseline of Fig 17(a). Computes the
    same probs as gate_stack but with p separate kernel launches."""
    outs = []
    for i in range(wg_stack.shape[0]):
        hn = rmsnorm(x, post_norm_w[i], cfg.norm_eps)
        outs.append(gating.gate_single(hn, wg_stack[i]))
    return jnp.stack(outs)


def post_norm(cfg, x, norm_w):
    """Post-attention RMSNorm — the expert input (separate unit so the
    coordinator normalizes once per layer, not once per expert)."""
    return rmsnorm(x, norm_w, cfg.norm_eps)


def expert_ffn_f32(x_normed, w1, w3, w2, gatew):
    """One expert at high precision; x_normed is the post-attn-normed
    hidden state. gatew[s]=0 rows are not routed here. -> weighted [S, d]."""
    return moe_ffn.ffn_f32(x_normed, w1, w3, w2, gatew)


def expert_ffn_quant(x_normed, w1p, w1s, w3p, w3s, w2p, w2s, gatew, *, fmt, group):
    """One expert at low precision (q8/q4/q2), packed per quantize.py."""
    return moe_ffn.ffn_quant(x_normed, w1p, w1s, w3p, w3s, w2p, w2s, gatew,
                             fmt=fmt, group=group)


# ---------------------------------------------------------------------------
# "fast" lowerings (§Perf): the same computations expressed as plain jnp so
# XLA fuses them into a handful of loops. On a real TPU the Pallas kernels
# above ARE the fast path (MXU-tiled, in-kernel dequant); under the CPU
# PJRT client Pallas runs in interpret mode (a correctness stand-in with a
# serial grid loop), so aot.py emits BOTH lowerings per expert unit and the
# rust engine picks `expert_fast_*` on CPU (EngineOptions::use_fast_ffn).
# pytest asserts fast == pallas to float tolerance.
# ---------------------------------------------------------------------------

def _dequant_jnp(packed, scales, rows, group, fmt):
    """jnp mirror of kernels.moe_ffn._dequant_tile (full-matrix, unfused)."""
    cols = packed.shape[-1]
    if fmt == "q8":
        codes = packed.astype(jnp.int8).astype(jnp.float32)
    elif fmt == "q4":
        nib0 = (packed & 0xF).astype(jnp.float32) - 8.0
        nib1 = (packed >> 4).astype(jnp.float32) - 8.0
        codes = jnp.stack([nib0, nib1], axis=1).reshape(rows, cols)
    elif fmt == "q2":
        fields = [((packed >> (2 * i)) & 0x3).astype(jnp.float32) - 2.0
                  for i in range(4)]
        codes = jnp.stack(fields, axis=1).reshape(rows, cols) + 0.5
    else:
        raise ValueError(fmt)
    return codes * jnp.repeat(scales, group, axis=0)


def expert_ffn_f32_fast(x_normed, w1, w3, w2, gatew):
    """XLA-fused SwiGLU expert FFN (identical math to expert_ffn_f32)."""
    h = jax.nn.silu(x_normed @ w1) * (x_normed @ w3)
    return (h @ w2) * gatew[:, None]


def expert_ffn_quant_fast(x_normed, w1p, w1s, w3p, w3s, w2p, w2s, gatew, *, fmt, group):
    d = x_normed.shape[1]
    ff = w1p.shape[-1]
    w1 = _dequant_jnp(w1p, w1s, d, group, fmt)
    w3 = _dequant_jnp(w3p, w3s, d, group, fmt)
    w2 = _dequant_jnp(w2p, w2s, ff, group, fmt)
    return expert_ffn_f32_fast(x_normed, w1, w3, w2, gatew)


def lm_head(cfg, x, norm_w, emb):
    """Final norm + tied-embedding logits. x: [S, d]; emb: [V, d] -> [S, V]."""
    hn = rmsnorm(x, norm_w, cfg.norm_eps)
    return hn @ emb.T


# ---------------------------------------------------------------------------
# Whole-model forward in pure JAX — the L2 oracle used by python tests and
# by the accuracy experiments (Fig 3b / Table 3 are generated from engine
# traces on the rust side; python/tests compare the rust engine against
# this function on identical weights).
# ---------------------------------------------------------------------------

def reference_forward(cfg, params, tokens, expert_override=None):
    """Run the full tiny model on a token sequence. Returns logits [S, V].

    params: dict with keys
      emb [V, d]; final_norm [d]
      per layer i: attn_norm.i, wq.i, wk.i, wv.i, wo.i, post_norm.i,
                   wg.i [d, E], expert.i.e.{w1,w3,w2}
    expert_override: optional fn(layer, expert, name, w) -> w allowing the
      accuracy experiments to swap in dequantized / skipped experts.
    """
    s = tokens.shape[0]
    d = cfg.d_model
    x = params["emb"][tokens]                     # [S, d]
    t = cfg.max_seq
    for li in range(cfg.n_layers):
        kc = jnp.zeros((t, cfg.n_kv_heads, cfg.head_dim), jnp.float32)
        vc = jnp.zeros((t, cfg.n_kv_heads, cfg.head_dim), jnp.float32)
        x, _, _ = attn_block(
            cfg, x, params[f"attn_norm.{li}"], params[f"wq.{li}"],
            params[f"wk.{li}"], params[f"wv.{li}"], params[f"wo.{li}"],
            kc, vc, jnp.array(0, jnp.int32))
        hn = rmsnorm(x, params[f"post_norm.{li}"], cfg.norm_eps)
        probs = jax.nn.softmax(hn @ params[f"wg.{li}"], axis=-1)   # [S, E]
        topv, topi = jax.lax.top_k(probs, cfg.top_k)
        # renormalize top-k gate weights (Mixtral convention)
        topv = topv / jnp.sum(topv, axis=-1, keepdims=True)
        moe_out = jnp.zeros_like(x)
        for e in range(cfg.n_experts):
            w1 = params[f"expert.{li}.{e}.w1"]
            w3 = params[f"expert.{li}.{e}.w3"]
            w2 = params[f"expert.{li}.{e}.w2"]
            if expert_override is not None:
                w1 = expert_override(li, e, "w1", w1)
                w3 = expert_override(li, e, "w3", w3)
                w2 = expert_override(li, e, "w2", w2)
            gw = jnp.sum(jnp.where(topi == e, topv, 0.0), axis=-1)  # [S]
            if w1 is None:  # expert skipped by override
                continue
            h = (hn * 1.0) @ w1
            out = (jax.nn.silu(h) * (hn @ w3)) @ w2
            moe_out = moe_out + out * gw[:, None]
        x = x + moe_out
    return lm_head(cfg, x, params["final_norm"], params["emb"])
