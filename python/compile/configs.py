"""Model configurations for the HOBBIT reproduction.

Two tiny MoE transformer configs mirror the structure (expert count, top-k,
layer count ratio) of the paper's evaluated models (Mixtral-8x7B, Phi-MoE)
at a scale that runs end-to-end on a single-CPU PJRT client.  The
paper-scale byte sizes used by the discrete-event simulator live on the
rust side (rust/src/sim/params.rs); these configs drive the *real* path.
"""

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    d_ff: int          # expert hidden dim
    n_experts: int     # experts per layer
    top_k: int
    n_heads: int       # query heads
    n_kv_heads: int
    vocab: int         # byte-level tokenizer: 256 bytes + BOS + EOS + PAD + UNK
    max_seq: int       # KV-cache capacity
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    # quantization group size along the contraction (d_model / d_ff) dim
    quant_group: int = 64

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def expert_params(self) -> int:
        """Parameters of one expert (w1, w3: [d, ff]; w2: [ff, d])."""
        return 3 * self.d_model * self.d_ff

    def expert_bytes(self, precision: str) -> int:
        """On-wire bytes of one expert at a given precision (incl. scales)."""
        n = self.expert_params
        groups = n // self.quant_group
        if precision == "f32":
            return 4 * n
        if precision == "q8":
            return n + 4 * groups
        if precision == "q4":
            return n // 2 + 4 * groups
        if precision == "q2":
            return n // 4 + 4 * groups
        raise ValueError(f"unknown precision {precision!r}")

    def to_dict(self) -> dict:
        d = asdict(self)
        d["head_dim"] = self.head_dim
        d["expert_params"] = self.expert_params
        d["expert_bytes"] = {p: self.expert_bytes(p) for p in PRECISIONS}
        return d


# Precisions, highest to lowest. "f32" stands in for the paper's fp16 class;
# q8 is the "int4-role" replacement (4.0x byte ratio, matching fp16:int4);
# q2 is the "int2-role" replacement for the q8-served model (4.0x again).
PRECISIONS = ("f32", "q8", "q4", "q2")

# Sequence-length variants we AOT-compile. Prefill runs in chunks of these
# sizes; decode uses S=1.
PREFILL_CHUNKS = (16, 128)
DECODE_S = 1
SEQ_VARIANTS = (DECODE_S,) + PREFILL_CHUNKS

# Expert-group launch widths for ragged grouped decode: a group of g
# routed rows pads to the smallest of these that fits (must match
# rust/src/runtime/manifest.rs GROUPED_WIDTHS). Only the expert FFN units
# compile at these widths — a grouped launch feeds one expert's record a
# slab of sorted tokens, so gate/head shapes are irrelevant and stay on
# SEQ_VARIANTS.
EXPERT_GROUP_WIDTHS = (2, 4, 8, 16, 32, 64)

# Stacking-Computer depths we AOT-compile (Fig 8 / Fig 17).
GATE_STACK_DEPTHS = (1, 2, 3, 4)

MIXTRAL_TINY = ModelConfig(
    name="mixtral-tiny",
    n_layers=8,
    d_model=256,
    d_ff=512,
    n_experts=8,
    top_k=2,
    n_heads=8,
    n_kv_heads=4,
    vocab=260,
    max_seq=512,
)

PHI_TINY = ModelConfig(
    name="phi-tiny",
    n_layers=8,
    d_model=256,
    d_ff=256,
    n_experts=16,
    top_k=2,
    n_heads=8,
    n_kv_heads=4,
    vocab=260,
    max_seq=512,
)

MODELS = {m.name: m for m in (MIXTRAL_TINY, PHI_TINY)}
