"""Generate and export model weights for the rust coordinator.

The paper evaluates pretrained Mixtral-8x7B / Phi-MoE checkpoints; those are
unavailable offline, so we export seeded random-init weights at matching
*structure* (DESIGN.md §Hardware-Adaptation).  Every expert is additionally
exported at every quantized precision so the Dynamic Expert Loader has real
byte-exact low-precision versions to fetch.

Layout under artifacts/weights/<model>/:

  weights.json               manifest: every tensor's file, shape, dtype
  manifest.json              model shape + per-record FNV-1a64 checksums
                             (the "integrity" section rust's
                             ExpertStore::load / verify-weights check)
  nonexpert.bin              all non-expert tensors, concatenated f32 LE
  experts_f32.bin            [layer][expert] (w1 | w3 | w2) f32 LE
  experts_q8.bin / _q4 / _q2 per-expert packed codes + scales, concatenated
                             in the same (layer, expert) order

Expert record layouts match rust/src/quant.rs + model/storage.rs exactly;
python/tests/test_weights.py round-trips them.
"""

import argparse
import json
import os
import time

import numpy as np

from .configs import MODELS, PRECISIONS
from . import quantize


FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x00000100000001B3
_U64 = (1 << 64) - 1


def fnv1a64(data: bytes) -> int:
    """FNV-1a 64 over raw record bytes — must match rust
    util/checksum.rs::fnv1a64 bit for bit (python/tests cross-check)."""
    h = FNV_OFFSET
    for b in data:
        h = ((h ^ b) * FNV_PRIME) & _U64
    return h


def _init(rng, shape, fan_in):
    return (rng.standard_normal(shape, dtype=np.float32)
            * np.float32(1.0 / np.sqrt(fan_in)))


def nonexpert_tensors(cfg, rng):
    """Ordered (name, array) list of all non-expert weights."""
    d, e, v = cfg.d_model, cfg.n_experts, cfg.vocab
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    out = [("emb", _init(rng, (v, d), d))]
    for li in range(cfg.n_layers):
        out += [
            (f"attn_norm.{li}", np.ones(d, np.float32)),
            (f"wq.{li}", _init(rng, (d, h * hd), d)),
            (f"wk.{li}", _init(rng, (d, hkv * hd), d)),
            (f"wv.{li}", _init(rng, (d, hkv * hd), d)),
            (f"wo.{li}", _init(rng, (h * hd, d), h * hd)),
            (f"post_norm.{li}", np.ones(d, np.float32)),
            (f"wg.{li}", _init(rng, (d, e), d)),
        ]
    out.append(("final_norm", np.ones(d, np.float32)))
    return out


def expert_tensors(cfg, rng, li, ei):
    d, ff = cfg.d_model, cfg.d_ff
    return [
        (f"expert.{li}.{ei}.w1", _init(rng, (d, ff), d)),
        (f"expert.{li}.{ei}.w3", _init(rng, (d, ff), d)),
        (f"expert.{li}.{ei}.w2", _init(rng, (ff, d), ff)),
    ]


def quantized_record(cfg, mats, fmt):
    """Packed bytes of one expert at `fmt`: for each of w1, w3, w2 in order,
    packed codes then scales (both C-order, LE)."""
    g = cfg.quant_group
    chunks = []
    for _, w in mats:
        packed, scales = quantize.quantize(w, g, fmt)
        chunks.append(packed.tobytes())
        chunks.append(scales.tobytes())
    return b"".join(chunks)


def export_model(cfg, out_root, seed):
    t0 = time.time()
    out_dir = os.path.join(out_root, "weights", cfg.name)
    os.makedirs(out_dir, exist_ok=True)
    rng = np.random.default_rng(seed)

    manifest = {"model": cfg.name, "seed": seed, "nonexpert": [], "experts": {}}

    # --- non-expert weights -------------------------------------------------
    off = 0
    with open(os.path.join(out_dir, "nonexpert.bin"), "wb") as f:
        for name, arr in nonexpert_tensors(cfg, rng):
            f.write(arr.tobytes())
            manifest["nonexpert"].append(
                {"name": name, "shape": list(arr.shape), "offset": off})
            off += arr.nbytes
    manifest["nonexpert_bytes"] = off

    # --- experts, all precisions -------------------------------------------
    files = {fmt: open(os.path.join(out_dir, f"experts_{fmt}.bin"), "wb")
             for fmt in PRECISIONS}
    rec_bytes = {fmt: None for fmt in PRECISIONS}
    checksums = {fmt: [] for fmt in PRECISIONS}
    for li in range(cfg.n_layers):
        for ei in range(cfg.n_experts):
            mats = expert_tensors(cfg, rng, li, ei)
            f32_rec = b"".join(w.tobytes() for _, w in mats)
            files["f32"].write(f32_rec)
            rec_bytes["f32"] = len(f32_rec)
            checksums["f32"].append(fnv1a64(f32_rec))
            for fmt in PRECISIONS[1:]:
                rec = quantized_record(cfg, mats, fmt)
                files[fmt].write(rec)
                rec_bytes[fmt] = len(rec)
                checksums[fmt].append(fnv1a64(rec))
    for f in files.values():
        f.close()
    manifest["experts"] = {
        "order": "layer-major (layer, expert)",
        "record_bytes": rec_bytes,
        "count": cfg.n_layers * cfg.n_experts,
    }

    with open(os.path.join(out_dir, "weights.json"), "w") as f:
        json.dump(manifest, f, indent=1)

    # store manifest: model shape + per-record checksums, the exact shape
    # rust's model/synth.rs::write_store_manifest emits (16 lowercase hex
    # digits — u64 does not survive JSON's f64, strings do)
    store_manifest = {
        "model": {
            "name": cfg.name,
            "n_layers": cfg.n_layers,
            "d_model": cfg.d_model,
            "d_ff": cfg.d_ff,
            "n_experts": cfg.n_experts,
            "top_k": cfg.top_k,
            "n_heads": cfg.n_heads,
            "n_kv_heads": cfg.n_kv_heads,
            "vocab": cfg.vocab,
            "max_seq": cfg.max_seq,
            "quant_group": cfg.quant_group,
            "expert_bytes": {p: cfg.expert_bytes(p) for p in PRECISIONS},
        },
        "integrity": {
            "algo": "fnv1a64",
            "records": {fmt: [f"{s:016x}" for s in sums]
                        for fmt, sums in checksums.items()},
        },
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(store_manifest, f, indent=1)
    total = sum(rec_bytes[p] for p in PRECISIONS) * cfg.n_layers * cfg.n_experts
    print(f"  [{cfg.name}] exported {cfg.n_layers}x{cfg.n_experts} experts, "
          f"{total/1e6:.0f} MB expert data, {off/1e6:.1f} MB non-expert "
          f"({time.time()-t0:.0f}s)")


def make_params(cfg, seed):
    """Regenerate the full parameter dict (same RNG stream as export_model)
    for model.reference_forward — used by python tests and the accuracy
    experiments to cross-check the rust engine on identical weights."""
    rng = np.random.default_rng(seed)
    params = dict(nonexpert_tensors(cfg, rng))
    for li in range(cfg.n_layers):
        for ei in range(cfg.n_experts):
            params.update(dict(expert_tensors(cfg, rng, li, ei)))
    return params


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", nargs="*", default=list(MODELS))
    ap.add_argument("--seed", type=int, default=20240917)
    args = ap.parse_args()
    for m in args.models:
        export_model(MODELS[m], args.out, args.seed)


if __name__ == "__main__":
    main()
