"""L1 Pallas kernel: the Stacking Computer (paper §3.3, Fig 8).

Computes the gating softmax of the *current* layer's input against the gate
matrices of the next `p` layers in ONE kernel launch — the paper's
observation is that the expert-count dimension E is tiny (8/16/64), so all
p gating matmuls fit in a single stacked computation whose cost is nearly
independent of p (reproduced by Fig 17(a) / `hobbit figures --fig 17a`).

Grid iterates over the p stacked layers; each step holds one [d, E] gate
matrix in VMEM and emits one softmax row.  interpret=True (CPU image).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gate_stack_kernel(xs_ref, wg_ref, o_ref):
    x = xs_ref[0]                         # [S, d] — this stacked layer's input
    logits = x @ wg_ref[0]                # [S, E]
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    o_ref[0] = e / jnp.sum(e, axis=-1, keepdims=True)


def gate_stack(xs, wg_stack):
    """Stacked gating probabilities.

    xs: [p, S, d] — the hidden state normalized with each stacked layer's
    own post-attention norm weight; wg_stack: [p, d, E] -> probs [p, S, E]
    """
    p, s, d = xs.shape
    e = wg_stack.shape[2]
    return pl.pallas_call(
        _gate_stack_kernel,
        grid=(p,),
        in_specs=[
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, d, e), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, s, e), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((p, s, e), jnp.float32),
        interpret=True,
    )(xs, wg_stack)


def gate_single(x, wg):
    """Single-layer gating probs: x [S, d], wg [d, E] -> [S, E]."""
    return gate_stack(x[None], wg[None])[0]
