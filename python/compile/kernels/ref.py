"""Pure-jnp correctness oracles for the L1 Pallas kernels.

Every kernel in moe_ffn.py / gating.py has a reference here; pytest
(python/tests/) asserts allclose between kernel and oracle across a
hypothesis sweep of shapes/precisions.
"""

import jax
import jax.numpy as jnp
import numpy as np

from .. import quantize


def silu(x):
    return x * jax.nn.sigmoid(x)


def ffn_ref(x, w1, w3, w2, gatew):
    """Weighted SwiGLU FFN: the f32 oracle."""
    h = silu(x @ w1) * (x @ w3)
    return (h @ w2) * gatew[:, None]


def ffn_quant_ref(x, w1p, w1s, w3p, w3s, w2p, w2s, gatew, *, fmt, group):
    """Quantized oracle: dequantize in numpy (the layout contract's own
    inverse), then run the f32 oracle."""
    d = x.shape[1]
    ff = w1p.shape[1]
    w1 = jnp.asarray(quantize.dequantize(np.asarray(w1p), np.asarray(w1s), d, group, fmt))
    w3 = jnp.asarray(quantize.dequantize(np.asarray(w3p), np.asarray(w3s), d, group, fmt))
    w2 = jnp.asarray(quantize.dequantize(np.asarray(w2p), np.asarray(w2s), ff, group, fmt))
    return ffn_ref(x, w1, w3, w2, gatew)


def gate_stack_ref(xs, wg_stack):
    """Stacked gating oracle: softmax(xs_i @ wg_i) for each stacked layer."""
    logits = jnp.einsum("psd,pde->pse", xs, wg_stack)
    return jax.nn.softmax(logits, axis=-1)
