"""L1 Pallas kernels: the expert-FFN hot spot, in f32 and group-quantized
(q8/q4/q2) variants with in-kernel dequantization.

This is the paper's compute hot path: a SwiGLU expert FFN
    y[s, :] = gatew[s] * ( (silu(x @ w1) * (x @ w3)) @ w2 )[s, :]
where the quantized variants carry w1/w3/w2 as packed sub-byte codes plus
per-(group, col) scales and dequantize *inside the matmul tile loop* — the
TPU rethink of the paper's CUDA dequant kernels (DESIGN.md
§Hardware-Adaptation):

  * grid iterates over tiles of the expert hidden dim (d_ff); each step
    holds one (d_model, FF_TILE) slab of w1/w3 and one (FF_TILE, d_model)
    slab of w2 in VMEM — the HBM→VMEM schedule the paper expressed with
    threadblocks is expressed here with a BlockSpec over the grid.
  * dequant (unpack + scale) happens on the VMEM-resident tile right before
    it feeds the MXU, so packed bytes are all that crosses HBM.
  * the output block is revisited across grid steps and accumulated,
    double-buffer friendly (no cross-step dependency except the += ).

Kernels MUST run with interpret=True on this CPU-only image (real TPU
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile of the expert hidden dimension processed per grid step. 128 matches
# the TPU lane width so dequantized tiles feed the MXU without re-layout.
FF_TILE = 128

_PACK = {"q8": 1, "q4": 2, "q2": 4}
_QOFF = {"q4": 8.0, "q2": 2.0}


def _silu(x):
    return x * jax.nn.sigmoid(x)


# ---------------------------------------------------------------------------
# f32 ("high precision") kernel
# ---------------------------------------------------------------------------

def _ffn_f32_kernel(x_ref, w1_ref, w3_ref, w2_ref, gw_ref, o_ref):
    """One grid step: one FF_TILE slab of the hidden dim."""
    x = x_ref[...]                       # [S, d]
    h = _silu(x @ w1_ref[...]) * (x @ w3_ref[...])   # [S, FF_TILE]
    part = h @ w2_ref[...]               # [S, d]

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += part

    @pl.when(pl.program_id(0) == pl.num_programs(0) - 1)
    def _scale():
        o_ref[...] *= gw_ref[...][:, None]


def ffn_f32(x, w1, w3, w2, gatew):
    """Weighted SwiGLU expert FFN, f32 weights.

    x: [S, d]; w1, w3: [d, ff]; w2: [ff, d]; gatew: [S] -> [S, d]
    """
    s, d = x.shape
    ff = w1.shape[1]
    assert ff % FF_TILE == 0, (ff, FF_TILE)
    grid = (ff // FF_TILE,)
    return pl.pallas_call(
        _ffn_f32_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((s, d), lambda i: (0, 0)),
            pl.BlockSpec((d, FF_TILE), lambda i: (0, i)),
            pl.BlockSpec((d, FF_TILE), lambda i: (0, i)),
            pl.BlockSpec((FF_TILE, d), lambda i: (i, 0)),
            pl.BlockSpec((s,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((s, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((s, d), jnp.float32),
        interpret=True,
    )(x, w1, w3, w2, gatew)


# ---------------------------------------------------------------------------
# Quantized kernels (q8 / q4 / q2) with in-kernel group dequant
# ---------------------------------------------------------------------------

def _dequant_tile(packed, scales, rows, group, fmt):
    """Dequantize a VMEM-resident packed tile.

    packed: u8 [rows/pack, cols]; scales: f32 [rows/group, cols]
    returns f32 [rows, cols].
    """
    pack = _PACK[fmt]
    cols = packed.shape[-1]
    if fmt == "q8":
        codes = packed.astype(jnp.int8).astype(jnp.float32)
    elif fmt == "q4":
        nib0 = (packed & 0xF).astype(jnp.float32) - _QOFF["q4"]
        nib1 = (packed >> 4).astype(jnp.float32) - _QOFF["q4"]
        # interleave rows: packed row r holds logical rows 2r (lo), 2r+1 (hi)
        codes = jnp.stack([nib0, nib1], axis=1).reshape(rows, cols)
    elif fmt == "q2":
        fields = [((packed >> (2 * i)) & 0x3).astype(jnp.float32) - _QOFF["q2"]
                  for i in range(4)]
        codes = jnp.stack(fields, axis=1).reshape(rows, cols)
        codes = codes + 0.5  # symmetric 4-level grid {-1.5,-0.5,0.5,1.5}
    else:
        raise ValueError(fmt)
    del pack
    s = jnp.repeat(scales, group, axis=0)  # [rows, cols]
    return codes * s


def _ffn_quant_kernel(x_ref, w1p_ref, w1s_ref, w3p_ref, w3s_ref,
                      w2p_ref, w2s_ref, gw_ref, o_ref, *, d, group, fmt):
    x = x_ref[...]
    w1 = _dequant_tile(w1p_ref[...], w1s_ref[...], d, group, fmt)
    w3 = _dequant_tile(w3p_ref[...], w3s_ref[...], d, group, fmt)
    w2 = _dequant_tile(w2p_ref[...], w2s_ref[...], FF_TILE, group, fmt)
    h = _silu(x @ w1) * (x @ w3)         # [S, FF_TILE]
    part = h @ w2                        # [S, d]

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += part

    @pl.when(pl.program_id(0) == pl.num_programs(0) - 1)
    def _scale():
        o_ref[...] *= gw_ref[...][:, None]


def ffn_quant(x, w1p, w1s, w3p, w3s, w2p, w2s, gatew, *, fmt, group):
    """Weighted SwiGLU expert FFN over packed quantized weights.

    Layouts follow python/compile/quantize.py:
      w1p, w3p: u8 [d/pack, ff];   w1s, w3s: f32 [d/group, ff]
      w2p:      u8 [ff/pack, d];   w2s:      f32 [ff/group, d]
    """
    s, d = x.shape
    ff = w1p.shape[1]
    pack = _PACK[fmt]
    assert ff % FF_TILE == 0 and d % group == 0 and FF_TILE % group == 0
    grid = (ff // FF_TILE,)
    kern = functools.partial(_ffn_quant_kernel, d=d, group=group, fmt=fmt)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((s, d), lambda i: (0, 0)),
            pl.BlockSpec((d // pack, FF_TILE), lambda i: (0, i)),
            pl.BlockSpec((d // group, FF_TILE), lambda i: (0, i)),
            pl.BlockSpec((d // pack, FF_TILE), lambda i: (0, i)),
            pl.BlockSpec((d // group, FF_TILE), lambda i: (0, i)),
            pl.BlockSpec((FF_TILE // pack, d), lambda i: (i, 0)),
            pl.BlockSpec((FF_TILE // group, d), lambda i: (i, 0)),
            pl.BlockSpec((s,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((s, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((s, d), jnp.float32),
        interpret=True,
    )(x, w1p, w1s, w3p, w3s, w2p, w2s, gatew)


def vmem_bytes(s: int, d: int, fmt: str, group: int) -> int:
    """HBM→VMEM bytes staged per grid step by the BlockSpecs (the quantity
    double-buffering must hide; DESIGN.md §Perf).  In a production Mosaic
    kernel the dequantized tile lives in vector registers feeding the MXU,
    so packed codes + scales are all that occupy weight VMEM."""
    if fmt == "f32":
        w = 4 * (2 * d * FF_TILE + FF_TILE * d)
    else:
        pack = _PACK[fmt]
        w = (2 * (d // pack) * FF_TILE + (FF_TILE // pack) * d)
        w += 4 * (2 * (d // group) * FF_TILE + (FF_TILE // group) * d)
    io = 4 * (s * d * 2 + s * FF_TILE + s)
    return w + io
