"""AOT-lower every L2 compute unit to HLO *text* for the rust runtime.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs, per model, under artifacts/<model>/:

  attn_s{S}.hlo.txt              S in {1, 16, 128}
  gate_p{p}_s1.hlo.txt           p in {1..4}   (Stacking Computer, decode)
  gate_seq_p{p}_s1.hlo.txt       p in {1..4}   (sequential baseline, Fig 17a)
  gate_p1_s{S}.hlo.txt           S in {16, 128} (prefill gating)
  expert_{fmt}_s{S}.hlo.txt      fmt in {f32, q8, q4, q2} x S in
                                 {1, 16, 128} u {2, 4, 8, 32, 64} (the
                                 extra widths are the ragged grouped-decode
                                 ladder; only the FFN units need them)
  head_s{S}.hlo.txt              S in {1, 16, 128}
  manifest.json                  shapes/dtypes/arity of every artifact

Every artifact returns a tuple (return_tuple=True) and is unwrapped with
to_tupleN() on the rust side.  Python runs ONCE at build time; the rust
binary is self-contained afterwards.
"""

import argparse
import functools
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs, model
from .configs import (
    MODELS,
    PRECISIONS,
    SEQ_VARIANTS,
    PREFILL_CHUNKS,
    GATE_STACK_DEPTHS,
    EXPERT_GROUP_WIDTHS,
)

F32 = jnp.float32
S32 = jnp.int32
U8 = jnp.uint8


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _pack_rows(rows, fmt):
    return {"q8": rows, "q4": rows // 2, "q2": rows // 4}[fmt]


def artifact_defs(cfg):
    """Yield (name, fn, arg_specs, n_outputs) for every compiled unit."""
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    h, hkv, hd, t = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.max_seq
    g = cfg.quant_group
    v = cfg.vocab

    defs = []

    for s in SEQ_VARIANTS:
        defs.append((
            f"attn_s{s}",
            functools.partial(model.attn_block, cfg),
            [spec((s, d)), spec((d,)), spec((d, h * hd)), spec((d, hkv * hd)),
             spec((d, hkv * hd)), spec((h * hd, d)), spec((t, hkv, hd)),
             spec((t, hkv, hd)), spec((), S32)],
            3,
        ))

    def gate_fn(x, pn, wg):
        probs = model.gate_stack(cfg, x, pn, wg)
        hn0 = model.rmsnorm(x, pn[0], cfg.norm_eps)
        return probs, hn0

    def gate_seq_fn(x, pn, wg):
        probs = model.gate_sequential(cfg, x, pn, wg)
        hn0 = model.rmsnorm(x, pn[0], cfg.norm_eps)
        return probs, hn0

    for p in GATE_STACK_DEPTHS:
        defs.append((
            f"gate_p{p}_s1", gate_fn,
            [spec((1, d)), spec((p, d)), spec((p, d, e))], 2))
        defs.append((
            f"gate_seq_p{p}_s1", gate_seq_fn,
            [spec((1, d)), spec((p, d)), spec((p, d, e))], 2))
    for s in PREFILL_CHUNKS:
        defs.append((
            f"gate_p1_s{s}", gate_fn,
            [spec((s, d)), spec((1, d)), spec((1, d, e))], 2))

    # expert FFN widths: the decode/prefill s-variants plus the grouped
    # ladder — grouped decode launches one expert over a slab of sorted
    # rows, so the FFN (and nothing else) compiles at every group width
    for s in sorted(set(SEQ_VARIANTS) | set(EXPERT_GROUP_WIDTHS)):
        # two lowerings per expert unit: the Pallas kernel (the real-TPU
        # hot path; interpret-mode on CPU) and the XLA-fused jnp variant
        # the engine serves from on the CPU PJRT client (§Perf)
        defs.append((
            f"expert_f32_s{s}", model.expert_ffn_f32,
            [spec((s, d)), spec((d, ff)), spec((d, ff)), spec((ff, d)),
             spec((s,))], 1))
        defs.append((
            f"expert_fast_f32_s{s}", model.expert_ffn_f32_fast,
            [spec((s, d)), spec((d, ff)), spec((d, ff)), spec((ff, d)),
             spec((s,))], 1))
        for fmt in PRECISIONS[1:]:
            qspecs = [spec((s, d)),
                      spec((_pack_rows(d, fmt), ff), U8), spec((d // g, ff)),
                      spec((_pack_rows(d, fmt), ff), U8), spec((d // g, ff)),
                      spec((_pack_rows(ff, fmt), d), U8), spec((ff // g, d)),
                      spec((s,))]
            fn = functools.partial(model.expert_ffn_quant, fmt=fmt, group=g)
            defs.append((f"expert_{fmt}_s{s}", fn, list(qspecs), 1))
            ffn = functools.partial(model.expert_ffn_quant_fast, fmt=fmt, group=g)
            defs.append((f"expert_fast_{fmt}_s{s}", ffn, list(qspecs), 1))

    for s in SEQ_VARIANTS:
        defs.append((
            f"head_s{s}",
            functools.partial(model.lm_head, cfg),
            [spec((s, d)), spec((d,)), spec((v, d))], 1))

    return defs


def build_model(cfg, out_root, only=None, force=False):
    out_dir = os.path.join(out_root, cfg.name)
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"model": cfg.to_dict(), "artifacts": {}}
    n_built = 0
    for name, fn, arg_specs, n_out in artifact_defs(cfg):
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        entry = {
            "file": f"{name}.hlo.txt",
            "inputs": [{"shape": list(a.shape), "dtype": str(a.dtype.name)}
                       for a in arg_specs],
            "outputs": n_out,
        }
        manifest["artifacts"][name] = entry
        if only and not any(tok in name for tok in only):
            continue
        if os.path.exists(path) and not force:
            continue
        t0 = time.time()
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        n_built += 1
        print(f"  [{cfg.name}] {name}: {len(text)/1e3:.0f} kB "
              f"({time.time()-t0:.1f}s)", flush=True)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return n_built


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts root")
    ap.add_argument("--models", nargs="*", default=list(MODELS),
                    help="subset of model names")
    ap.add_argument("--only", nargs="*", default=None,
                    help="only build artifacts whose name contains any token")
    ap.add_argument("--force", action="store_true", help="rebuild even if present")
    args = ap.parse_args()

    t0 = time.time()
    total = 0
    for mname in args.models:
        cfg = MODELS[mname]
        print(f"building artifacts for {mname} ...", flush=True)
        total += build_model(cfg, args.out, only=args.only, force=args.force)
    print(f"built {total} artifacts in {time.time()-t0:.0f}s -> {args.out}")


if __name__ == "__main__":
    main()
