"""The python FNV-1a64 mirror must match rust util/checksum.rs bit for
bit (same standard test vectors), and the exported manifest.json must
carry checksums that re-verify against the record files on disk."""

import json
import os

import pytest

from compile import gen_weights
from compile.configs import MIXTRAL_TINY, PRECISIONS

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_fnv1a64_known_vectors():
    # the same standard vectors rust/src/util/checksum.rs pins
    assert gen_weights.fnv1a64(b"") == 0xCBF29CE484222325
    assert gen_weights.fnv1a64(b"a") == 0xAF63DC4C8601EC8C
    assert gen_weights.fnv1a64(b"foobar") == 0x85944171F73967E8


def test_fnv1a64_detects_a_bit_flip():
    rec = bytes(i % 251 for i in range(4096))
    flipped = bytearray(rec)
    flipped[1234] ^= 0x10
    assert gen_weights.fnv1a64(rec) != gen_weights.fnv1a64(bytes(flipped))


def test_exported_manifest_checksums_reverify():
    cfg = MIXTRAL_TINY
    wdir = os.path.join(ART, "weights", cfg.name)
    path = os.path.join(wdir, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("weights not exported")
    with open(path) as f:
        man = json.load(f)
    assert man["integrity"]["algo"] == "fnv1a64"
    n = cfg.n_layers * cfg.n_experts
    for fmt in PRECISIONS:
        sums = man["integrity"]["records"][fmt]
        assert len(sums) == n
        rb = cfg.expert_bytes(fmt)
        with open(os.path.join(wdir, f"experts_{fmt}.bin"), "rb") as f:
            blob = f.read()
        assert len(blob) == rb * n
        for i in range(n):
            got = gen_weights.fnv1a64(blob[i * rb:(i + 1) * rb])
            assert f"{got:016x}" == sums[i], f"{fmt} record {i}"
