"""L1 kernel vs pure-jnp oracle — the CORE correctness signal.

hypothesis sweeps shapes and precisions; every pallas kernel must match
its ref.py oracle to float tolerance.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import quantize
from compile.kernels import moe_ffn, gating, ref

FMTS = ("q8", "q4", "q2")


def _mk(seed, *shape, scale=0.05):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape, dtype=np.float32) * np.float32(scale))


def _ffn_args(seed, s, d, ff):
    x = _mk(seed, s, d, scale=1.0)
    w1 = _mk(seed + 1, d, ff)
    w3 = _mk(seed + 2, d, ff)
    w2 = _mk(seed + 3, ff, d)
    gw = np.abs(_mk(seed + 4, s, scale=1.0))
    return x, w1, w3, w2, gw


@pytest.mark.parametrize("s", [1, 4, 16, 128])
def test_ffn_f32_matches_ref(s):
    x, w1, w3, w2, gw = map(jnp.asarray, _ffn_args(s, s, 256, 512))
    y = moe_ffn.ffn_f32(x, w1, w3, w2, gw)
    yr = ref.ffn_ref(x, w1, w3, w2, gw)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("fmt", FMTS)
@pytest.mark.parametrize("s", [1, 16])
def test_ffn_quant_matches_ref(fmt, s):
    x, w1, w3, w2, gw = _ffn_args(7, s, 256, 512)
    g = 64
    packs = []
    for w in (w1, w3, w2):
        p, sc = quantize.quantize(w, g, fmt)
        packs += [jnp.asarray(p), jnp.asarray(sc)]
    y = moe_ffn.ffn_quant(jnp.asarray(x), *packs, jnp.asarray(gw), fmt=fmt, group=g)
    yr = ref.ffn_quant_ref(jnp.asarray(x), *packs, jnp.asarray(gw), fmt=fmt, group=g)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-5, atol=2e-6)


def test_ffn_gate_weight_scales_rows():
    """gatew scales each row of the output independently."""
    x, w1, w3, w2, _ = map(jnp.asarray, _ffn_args(11, 4, 256, 128))
    ones = jnp.ones(4)
    base = moe_ffn.ffn_f32(x, w1, w3, w2, ones)
    gw = jnp.asarray([0.0, 0.5, 1.0, 2.0], jnp.float32)
    y = moe_ffn.ffn_f32(x, w1, w3, w2, gw)
    np.testing.assert_allclose(np.asarray(y), np.asarray(base * gw[:, None]),
                               rtol=1e-5, atol=1e-7)


def test_ffn_zero_gate_gives_zero():
    x, w1, w3, w2, _ = map(jnp.asarray, _ffn_args(13, 2, 128, 128))
    y = moe_ffn.ffn_f32(x, w1, w3, w2, jnp.zeros(2))
    assert float(jnp.max(jnp.abs(y))) == 0.0


@settings(max_examples=12, deadline=None)
@given(
    s=st.sampled_from([1, 2, 8]),
    d=st.sampled_from([64, 128, 256]),
    ff=st.sampled_from([128, 256, 512]),
    seed=st.integers(0, 10_000),
)
def test_ffn_f32_property(s, d, ff, seed):
    x, w1, w3, w2, gw = map(jnp.asarray, _ffn_args(seed, s, d, ff))
    y = moe_ffn.ffn_f32(x, w1, w3, w2, gw)
    yr = ref.ffn_ref(x, w1, w3, w2, gw)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=5e-5, atol=5e-6)


@settings(max_examples=10, deadline=None)
@given(
    fmt=st.sampled_from(FMTS),
    d=st.sampled_from([128, 256]),
    ff=st.sampled_from([128, 256]),
    seed=st.integers(0, 10_000),
)
def test_ffn_quant_property(fmt, d, ff, seed):
    s, g = 2, 64
    x, w1, w3, w2, gw = _ffn_args(seed, s, d, ff)
    packs = []
    for w in (w1, w3, w2):
        p, sc = quantize.quantize(w, g, fmt)
        packs += [jnp.asarray(p), jnp.asarray(sc)]
    y = moe_ffn.ffn_quant(jnp.asarray(x), *packs, jnp.asarray(gw), fmt=fmt, group=g)
    yr = ref.ffn_quant_ref(jnp.asarray(x), *packs, jnp.asarray(gw), fmt=fmt, group=g)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=5e-5, atol=5e-6)


@pytest.mark.parametrize("p", [1, 2, 3, 4])
@pytest.mark.parametrize("e", [8, 16])
def test_gate_stack_matches_ref(p, e):
    xs = jnp.asarray(_mk(p * 31 + e, p, 1, 256, scale=1.0))
    wg = jnp.asarray(_mk(p * 37 + e, p, 256, e, scale=0.1))
    y = gating.gate_stack(xs, wg)
    yr = ref.gate_stack_ref(xs, wg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-5, atol=1e-6)


def test_gate_stack_rows_sum_to_one():
    xs = jnp.asarray(_mk(3, 2, 16, 128, scale=1.0))
    wg = jnp.asarray(_mk(4, 2, 128, 8, scale=0.2))
    y = np.asarray(gating.gate_stack(xs, wg))
    np.testing.assert_allclose(y.sum(-1), np.ones((2, 16)), rtol=1e-5)


def test_gate_single_consistency():
    x = jnp.asarray(_mk(5, 4, 128, scale=1.0))
    wg = jnp.asarray(_mk(6, 128, 8, scale=0.2))
    a = gating.gate_single(x, wg)
    b = gating.gate_stack(x[None], wg[None])[0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_vmem_estimate_monotone_in_precision():
    """Packed formats shrink the VMEM working set (perf model sanity)."""
    sizes = [moe_ffn.vmem_bytes(1, 256, f, 64) for f in ("f32", "q8", "q4", "q2")]
    assert sizes[1] < sizes[0] and sizes[3] < sizes[2] <= sizes[1]


# --- fast (XLA-fused) lowerings must equal the pallas kernels (§Perf) ----

def test_fast_ffn_f32_matches_pallas():
    from compile import model as m
    x, w1, w3, w2, gw = map(jnp.asarray, _ffn_args(21, 4, 256, 512))
    a = m.expert_ffn_f32(x, w1, w3, w2, gw)
    b = m.expert_ffn_f32_fast(x, w1, w3, w2, gw)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("fmt", FMTS)
def test_fast_ffn_quant_matches_pallas(fmt):
    from compile import model as m
    g = 64
    x, w1, w3, w2, gw = _ffn_args(23, 2, 256, 512)
    packs = []
    for w in (w1, w3, w2):
        p, sc = quantize.quantize(w, g, fmt)
        packs += [jnp.asarray(p), jnp.asarray(sc)]
    a = m.expert_ffn_quant(jnp.asarray(x), *packs, jnp.asarray(gw), fmt=fmt, group=g)
    b = m.expert_ffn_quant_fast(jnp.asarray(x), *packs, jnp.asarray(gw), fmt=fmt, group=g)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-6)
