"""AOT pipeline tests: artifact definitions cover the engine's needs and
lowered HLO text is loadable-shaped (ENTRY present, tuple root)."""

import json
import os

import jax
import pytest

from compile import aot
from compile.configs import MODELS, MIXTRAL_TINY, SEQ_VARIANTS, PRECISIONS, GATE_STACK_DEPTHS

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_artifact_defs_complete():
    names = {n for n, *_ in aot.artifact_defs(MIXTRAL_TINY)}
    for s in SEQ_VARIANTS:
        assert f"attn_s{s}" in names
        assert f"head_s{s}" in names
        for fmt in PRECISIONS:
            assert f"expert_{fmt}_s{s}" in names
    for p in GATE_STACK_DEPTHS:
        assert f"gate_p{p}_s1" in names
        assert f"gate_seq_p{p}_s1" in names


def test_artifact_defs_unique_names():
    for cfg in MODELS.values():
        names = [n for n, *_ in aot.artifact_defs(cfg)]
        assert len(names) == len(set(names))


def test_lower_one_artifact_to_hlo_text():
    cfg = MIXTRAL_TINY
    defs = {n: (fn, specs) for n, fn, specs, _ in aot.artifact_defs(cfg)}
    fn, specs = defs["head_s1"]
    text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    assert "ENTRY" in text and "HloModule" in text
    # tuple root (return_tuple=True) so rust unwraps with to_tupleN
    assert "tuple(" in text or "tuple " in text


@pytest.mark.skipif(not os.path.isdir(os.path.join(ART, "mixtral-tiny")),
                    reason="artifacts not built")
@pytest.mark.parametrize("mname", list(MODELS))
def test_manifest_matches_files(mname):
    mdir = os.path.join(ART, mname)
    with open(os.path.join(mdir, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["model"]["name"] == mname
    for name, entry in manifest["artifacts"].items():
        path = os.path.join(mdir, entry["file"])
        assert os.path.exists(path), f"missing artifact {name}"
        assert entry["outputs"] >= 1
        for inp in entry["inputs"]:
            assert inp["dtype"] in ("float32", "int32", "uint8")


@pytest.mark.skipif(not os.path.isdir(os.path.join(ART, "mixtral-tiny")),
                    reason="artifacts not built")
def test_hlo_text_parses_headers():
    mdir = os.path.join(ART, "mixtral-tiny")
    for fn in sorted(os.listdir(mdir)):
        if fn.endswith(".hlo.txt"):
            with open(os.path.join(mdir, fn)) as f:
                head = f.read(4096)
            assert head.startswith("HloModule"), fn


def test_expert_bytes_ratios():
    """The loading-byte ratios that drive the whole paper: low-precision
    replacements are ~4x cheaper per step of the precision ladder."""
    cfg = MIXTRAL_TINY
    b = {p: cfg.expert_bytes(p) for p in PRECISIONS}
    assert 3.5 < b["f32"] / b["q8"] <= 4.0
    # scales overhead costs q2 a bit more, relatively
    assert 3.2 <= b["q8"] / b["q2"] <= 4.0
    assert b["q8"] > b["q4"] > b["q2"]
