"""Unit + property tests for the group-quantization layout contract."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quantize
from compile.quantize import quantize as q, dequantize, unpack_codes, group_scales

FMTS = ("q8", "q4", "q2")


def rand_w(rows, cols, seed=0, scale=0.05):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal((rows, cols), dtype=np.float32) * np.float32(scale))


@pytest.mark.parametrize("fmt", FMTS)
def test_packed_shape(fmt):
    w = rand_w(128, 32)
    packed, scales = q(w, 64, fmt)
    pack = {"q8": 1, "q4": 2, "q2": 4}[fmt]
    assert packed.shape == (128 // pack, 32)
    assert packed.dtype == np.uint8
    assert scales.shape == (2, 32)
    assert scales.dtype == np.float32


@pytest.mark.parametrize("fmt", FMTS)
def test_roundtrip_error_bound(fmt):
    """Dequantized weights stay within half a quantization step."""
    w = rand_w(256, 64, seed=1)
    packed, scales = q(w, 64, fmt)
    wd = dequantize(packed, scales, 256, 64, fmt)
    step = np.repeat(scales, 64, axis=0)  # one code unit
    err = np.abs(wd - w)
    # clipping can only bring values inward; interior codes are within step/2
    assert np.all(err <= step * 0.5 + 1e-6)


def test_error_ordering():
    """Coarser formats are strictly worse on average."""
    w = rand_w(512, 128, seed=2)
    errs = []
    for fmt in FMTS:
        wd = quantize.quantize_roundtrip(w, 64, fmt)
        errs.append(float(np.abs(wd - w).mean()))
    assert errs[0] < errs[1] < errs[2]


def test_q8_matches_int8_view():
    w = rand_w(64, 8)
    packed, scales = q(w, 64, "q8")
    codes = packed.view(np.int8)
    assert codes.min() >= -127 and codes.max() <= 127
    wd = codes.astype(np.float32) * np.repeat(scales, 64, axis=0)
    np.testing.assert_allclose(wd, dequantize(packed, scales, 64, 64, "q8"))


def test_zero_group_no_nan():
    w = np.zeros((64, 4), np.float32)
    packed, scales = q(w, 64, "q2")
    wd = dequantize(packed, scales, 64, 64, "q2")
    assert np.all(np.isfinite(wd))
    # q2 has no exact-zero level; magnitudes are <= half step of scale 1.0
    assert np.all(np.abs(wd) <= 0.5)


@pytest.mark.parametrize("fmt", FMTS)
def test_unpack_inverts_pack(fmt):
    w = rand_w(128, 16, seed=3)
    packed, scales = q(w, 32, fmt)
    codes = unpack_codes(packed, 128, fmt)
    # re-packing the codes must give identical bytes
    lvl = codes + (0.5 if fmt == "q2" else 0.0)
    wd = lvl * np.repeat(scales, 32, axis=0)
    p2, s2 = q(wd.astype(np.float32), 32, fmt)
    np.testing.assert_array_equal(packed, p2)


@settings(max_examples=30, deadline=None)
@given(
    rows=st.sampled_from([64, 128, 256]),
    cols=st.integers(1, 24),
    group=st.sampled_from([32, 64]),
    fmt=st.sampled_from(FMTS),
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(1e-4, 10.0),
)
def test_roundtrip_property(rows, cols, group, fmt, seed, scale):
    w = rand_w(rows, cols, seed=seed, scale=scale)
    packed, scales = q(w, group, fmt)
    wd = dequantize(packed, scales, rows, group, fmt)
    assert wd.shape == w.shape
    assert np.all(np.isfinite(wd))
    step = np.repeat(scales, group, axis=0)
    assert np.all(np.abs(wd - w) <= step * 0.5 + 1e-5 * scale)


@settings(max_examples=20, deadline=None)
@given(fmt=st.sampled_from(FMTS), seed=st.integers(0, 1000))
def test_scale_invariance(fmt, seed):
    """quantize(c*W) == c * quantize(W) up to float rounding."""
    w = rand_w(128, 8, seed=seed)
    a = quantize.quantize_roundtrip(w, 64, fmt)
    b = quantize.quantize_roundtrip((w * 4.0).astype(np.float32), 64, fmt) / 4.0
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-7)


def test_group_scales_positive():
    w = rand_w(128, 8, seed=9)
    s = group_scales(w, 64, "q8")
    assert np.all(s > 0)
