"""L2 model-graph tests: attention semantics, gating equivalences,
reference forward sanity."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model, gen_weights
from compile.configs import MIXTRAL_TINY, PHI_TINY


CFG = MIXTRAL_TINY


def _attn_weights(seed=0):
    rng = np.random.default_rng(seed)
    d, h, hkv, hd = CFG.d_model, CFG.n_heads, CFG.n_kv_heads, CFG.head_dim

    def mk(*shape, fan=None):
        fan = fan or shape[0]
        return jnp.asarray(rng.standard_normal(shape, dtype=np.float32)
                           * np.float32(1 / np.sqrt(fan)))

    return dict(
        norm_w=jnp.ones(d), wq=mk(d, h * hd), wk=mk(d, hkv * hd),
        wv=mk(d, hkv * hd), wo=mk(h * hd, d))


def _empty_cache():
    return (jnp.zeros((CFG.max_seq, CFG.n_kv_heads, CFG.head_dim)),
            jnp.zeros((CFG.max_seq, CFG.n_kv_heads, CFG.head_dim)))


def test_attn_shapes():
    w = _attn_weights()
    kc, vc = _empty_cache()
    x = jnp.asarray(np.random.default_rng(1).standard_normal((16, CFG.d_model), dtype=np.float32))
    y, kc2, vc2 = model.attn_block(CFG, x, w["norm_w"], w["wq"], w["wk"],
                                   w["wv"], w["wo"], kc, vc, jnp.array(0, jnp.int32))
    assert y.shape == x.shape and kc2.shape == kc.shape and vc2.shape == vc.shape


def test_attn_cache_written_at_pos():
    w = _attn_weights()
    kc, vc = _empty_cache()
    x = jnp.asarray(np.random.default_rng(2).standard_normal((4, CFG.d_model), dtype=np.float32))
    _, kc2, _ = model.attn_block(CFG, x, w["norm_w"], w["wq"], w["wk"],
                                 w["wv"], w["wo"], kc, vc, jnp.array(32, jnp.int32))
    assert float(jnp.abs(kc2[:32]).max()) == 0.0
    assert float(jnp.abs(kc2[32:36]).max()) > 0.0
    assert float(jnp.abs(kc2[36:]).max()) == 0.0


def test_attn_chunked_equals_full():
    """Prefilling in two chunks must equal one-shot prefill (causality)."""
    w = _attn_weights()
    x = jnp.asarray(np.random.default_rng(3).standard_normal((32, CFG.d_model), dtype=np.float32))
    kc, vc = _empty_cache()
    y_full, _, _ = model.attn_block(CFG, x, w["norm_w"], w["wq"], w["wk"],
                                    w["wv"], w["wo"], kc, vc, jnp.array(0, jnp.int32))
    kc, vc = _empty_cache()
    y1, kc, vc = model.attn_block(CFG, x[:16], w["norm_w"], w["wq"], w["wk"],
                                  w["wv"], w["wo"], kc, vc, jnp.array(0, jnp.int32))
    y2, kc, vc = model.attn_block(CFG, x[16:], w["norm_w"], w["wq"], w["wk"],
                                  w["wv"], w["wo"], kc, vc, jnp.array(16, jnp.int32))
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(jnp.concatenate([y1, y2])),
                               rtol=1e-4, atol=1e-5)


def test_attn_decode_matches_prefill_row():
    """Decoding token 8 after prefilling 8 gives the same row as a 9-token
    prefill — the real-path decode loop is consistent with prefill."""
    w = _attn_weights()
    x = jnp.asarray(np.random.default_rng(4).standard_normal((9, CFG.d_model), dtype=np.float32))
    kc, vc = _empty_cache()
    y_full, _, _ = model.attn_block(CFG, x, w["norm_w"], w["wq"], w["wk"],
                                    w["wv"], w["wo"], kc, vc, jnp.array(0, jnp.int32))
    kc, vc = _empty_cache()
    _, kc, vc = model.attn_block(CFG, x[:8], w["norm_w"], w["wq"], w["wk"],
                                 w["wv"], w["wo"], kc, vc, jnp.array(0, jnp.int32))
    y_dec, _, _ = model.attn_block(CFG, x[8:9], w["norm_w"], w["wq"], w["wk"],
                                   w["wv"], w["wo"], kc, vc, jnp.array(8, jnp.int32))
    np.testing.assert_allclose(np.asarray(y_full[8:9]), np.asarray(y_dec),
                               rtol=1e-4, atol=1e-5)


def test_gate_stack_matches_sequential():
    """Fig 17(a): the Stacking Computer computes exactly what the naive
    sequential loop computes."""
    rng = np.random.default_rng(5)
    p, d, e = 3, CFG.d_model, CFG.n_experts
    x = jnp.asarray(rng.standard_normal((1, d), dtype=np.float32))
    pn = jnp.asarray(np.abs(rng.standard_normal((p, d), dtype=np.float32)))
    wg = jnp.asarray(rng.standard_normal((p, d, e), dtype=np.float32) * np.float32(0.1))
    a = model.gate_stack(CFG, x, pn, wg)
    b = model.gate_sequential(CFG, x, pn, wg)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("cfg", [MIXTRAL_TINY, PHI_TINY], ids=lambda c: c.name)
def test_reference_forward_shapes_and_finite(cfg):
    params = {k: jnp.asarray(v) for k, v in gen_weights.make_params(cfg, 7).items()}
    toks = jnp.asarray(np.arange(12) % 250, jnp.int32)
    logits = model.reference_forward(cfg, params, toks)
    assert logits.shape == (12, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_reference_forward_expert_override_changes_output():
    cfg = MIXTRAL_TINY
    params = {k: jnp.asarray(v) for k, v in gen_weights.make_params(cfg, 7).items()}
    toks = jnp.asarray(np.arange(8) % 250, jnp.int32)
    base = model.reference_forward(cfg, params, toks)

    def zero_expert(li, e, name, w):
        return None if (li == 0 and e == 0) else w

    # skipping an expert must change the logits unless it was never routed;
    # with 8 tokens x 8 layers x top-2 this is overwhelmingly likely.
    skipped = model.reference_forward(cfg, params, toks, expert_override=zero_expert)
    assert float(jnp.max(jnp.abs(base - skipped))) >= 0.0  # well-defined
    assert skipped.shape == base.shape


def test_rmsnorm_unit_scale():
    x = jnp.asarray(np.random.default_rng(8).standard_normal((4, 64), dtype=np.float32)) * 10
    y = model.rmsnorm(x, jnp.ones(64), 1e-5)
    rms = np.sqrt(np.mean(np.asarray(y) ** 2, axis=-1))
    np.testing.assert_allclose(rms, np.ones(4), rtol=1e-3)


def test_rope_preserves_norm():
    q = jnp.asarray(np.random.default_rng(9).standard_normal((4, 2, 32), dtype=np.float32))
    q2 = model.rope(q, jnp.array(5.0), 10000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(q), axis=-1),
                               np.linalg.norm(np.asarray(q2), axis=-1), rtol=1e-5)
