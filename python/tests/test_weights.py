"""Weight export round-trip: the bytes gen_weights.py writes are exactly
what make_params() regenerates, at every precision (the rust side reads
the same files — rust/tests/storage_roundtrip.rs checks from that end)."""

import json
import os

import numpy as np
import pytest

from compile import gen_weights, quantize
from compile.configs import MIXTRAL_TINY, MODELS, PRECISIONS

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
SEED = 20240917


def _wdir(name):
    return os.path.join(ART, "weights", name)


built = os.path.exists(os.path.join(_wdir("mixtral-tiny"), "weights.json"))
pytestmark = pytest.mark.skipif(not built, reason="weights not exported")


def test_nonexpert_roundtrip():
    cfg = MIXTRAL_TINY
    with open(os.path.join(_wdir(cfg.name), "weights.json")) as f:
        man = json.load(f)
    blob = np.fromfile(os.path.join(_wdir(cfg.name), "nonexpert.bin"), np.float32)
    params = gen_weights.make_params(cfg, SEED)
    for ent in man["nonexpert"]:
        arr = params[ent["name"]]
        n = int(np.prod(ent["shape"]))
        got = blob[ent["offset"] // 4: ent["offset"] // 4 + n].reshape(ent["shape"])
        np.testing.assert_array_equal(got, arr, err_msg=ent["name"])


def test_expert_f32_roundtrip():
    cfg = MIXTRAL_TINY
    params = gen_weights.make_params(cfg, SEED)
    rec = cfg.expert_params  # floats per expert
    blob = np.fromfile(os.path.join(_wdir(cfg.name), "experts_f32.bin"), np.float32)
    assert blob.size == rec * cfg.n_layers * cfg.n_experts
    # spot-check first, middle, last expert
    for li, ei in [(0, 0), (cfg.n_layers // 2, 3), (cfg.n_layers - 1, cfg.n_experts - 1)]:
        idx = li * cfg.n_experts + ei
        got = blob[idx * rec:(idx + 1) * rec]
        d, ff = cfg.d_model, cfg.d_ff
        w1 = got[:d * ff].reshape(d, ff)
        w3 = got[d * ff:2 * d * ff].reshape(d, ff)
        w2 = got[2 * d * ff:].reshape(ff, d)
        np.testing.assert_array_equal(w1, params[f"expert.{li}.{ei}.w1"])
        np.testing.assert_array_equal(w3, params[f"expert.{li}.{ei}.w3"])
        np.testing.assert_array_equal(w2, params[f"expert.{li}.{ei}.w2"])


@pytest.mark.parametrize("fmt", PRECISIONS[1:])
def test_expert_quant_record_layout(fmt):
    cfg = MIXTRAL_TINY
    params = gen_weights.make_params(cfg, SEED)
    with open(os.path.join(_wdir(cfg.name), "weights.json")) as f:
        man = json.load(f)
    rec = man["experts"]["record_bytes"][fmt]
    assert rec == cfg.expert_bytes(fmt)
    path = os.path.join(_wdir(cfg.name), f"experts_{fmt}.bin")
    blob = open(path, "rb").read()
    assert len(blob) == rec * cfg.n_layers * cfg.n_experts
    # decode expert (0, 1) and compare to direct quantization
    li, ei = 0, 1
    raw = blob[(li * cfg.n_experts + ei) * rec:(li * cfg.n_experts + ei + 1) * rec]
    g, d, ff = cfg.quant_group, cfg.d_model, cfg.d_ff
    pack = {"q8": 1, "q4": 2, "q2": 4}[fmt]
    off = 0
    for name, rows, cols in (("w1", d, ff), ("w3", d, ff), ("w2", ff, d)):
        nb = rows // pack * cols
        packed = np.frombuffer(raw[off:off + nb], np.uint8).reshape(rows // pack, cols)
        off += nb
        ns = rows // g * cols * 4
        scales = np.frombuffer(raw[off:off + ns], np.float32).reshape(rows // g, cols)
        off += ns
        w = params[f"expert.{li}.{ei}.{name}"]
        p2, s2 = quantize.quantize(w, g, fmt)
        np.testing.assert_array_equal(packed, p2, err_msg=name)
        np.testing.assert_array_equal(scales, s2, err_msg=name)
    assert off == rec


@pytest.mark.parametrize("mname", list(MODELS))
def test_quant_quality_ladder(mname):
    """Dequantized experts approximate f32 better at higher precision —
    the premise of the paper's Fig 3(b)."""
    cfg = MODELS[mname]
    params = gen_weights.make_params(cfg, SEED)
    w = params["expert.0.0.w1"]
    errs = [np.abs(quantize.quantize_roundtrip(w, cfg.quant_group, f) - w).mean()
            for f in PRECISIONS[1:]]
    assert errs[0] < errs[1] < errs[2]
