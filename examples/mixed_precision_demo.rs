//! Mixed-precision mechanics demo: how the Expert Scorer (Eq. 2) turns
//! gate distributions into precision decisions, and what that does to the
//! live engine's loading behaviour (bytes moved, speed) vs the
//! all-high-precision baseline — the Fig 16 ablation at tiny scale.
//!
//! ```sh
//! cargo run --release --example mixed_precision_demo
//! ```

use hobbit::baselines;
use hobbit::config::HardwareConfig;
use hobbit::coordinator::{Coordinator, Request};
use hobbit::engine::Engine;
use hobbit::loader::scorer::{self, Class};

fn main() -> anyhow::Result<()> {
    println!("== Expert Scorer walkthrough (T1=0.6, T2=0.9) ==\n");
    let cases: [(&str, Vec<f32>); 4] = [
        ("balanced gate", vec![0.48, 0.46, 0.03, 0.03]),
        ("moderate dominance", vec![0.70, 0.24, 0.03, 0.03]),
        ("strong dominance", vec![0.92, 0.05, 0.02, 0.01]),
        ("three-way split", vec![0.40, 0.35, 0.20, 0.05]),
    ];
    for (name, probs) in &cases {
        println!("{name}: gate = {probs:?}");
        for d in scorer::decide(probs, 2, 0.6, 0.9, true) {
            let cls = match d.class {
                Class::Hi => "HIGH precision (f32)",
                Class::Lo => "LOW precision (q8, 4x fewer bytes)",
                Class::Skip => "SKIPPED",
            };
            println!(
                "    expert {}: weight {:.2}, unimportance score {:.2} -> {cls}",
                d.expert, d.gate_weight, d.score
            );
        }
    }

    let artifacts = std::path::PathBuf::from("artifacts");
    if !artifacts.join("mixtral-tiny/manifest.json").exists() {
        println!("\n(artifacts not built; run `make artifacts` for the live comparison)");
        return Ok(());
    }

    println!("\n== live engine: dynamic mixed-precision loading vs all-high ==\n");
    let prompt = "the dynamic expert loader fetches low precision versions of unimportant experts";
    let mut results = Vec::new();
    for (name, opts) in [
        ("HOBBIT (mixed precision)", baselines::real_hobbit(HardwareConfig::orin_real())),
        ("no dynamic loading (all f32)", baselines::real_no_dynamic(HardwareConfig::orin_real())),
    ] {
        let engine = Engine::new(&artifacts, "mixtral-tiny", opts)?;
        let mut coord = Coordinator::new(engine);
        let r = coord.generate(&Request::new(1, prompt, 24))?;
        coord.sync_report();
        let loader = coord.report.loader.clone();
        println!(
            "{name:<32} decode {:>6.2} tok/s | {:>6.1} MB loaded | loads hi/lo {} / {} | skipped {}",
            r.metrics.decode_tps(),
            loader.bytes_loaded as f64 / 1e6,
            loader.ondemand_loads[0],
            loader.ondemand_loads[1],
            loader.skipped,
        );
        results.push((name, r.metrics.decode_tps(), loader.bytes_loaded));
    }
    let speedup = results[0].1 / results[1].1.max(1e-9);
    let byte_ratio = results[1].2 as f64 / results[0].2.max(1) as f64;
    println!(
        "\ndynamic loading speedup: {speedup:.2}x  (paper Fig 16: 1.19x-1.57x); bytes reduced {byte_ratio:.2}x"
    );
    Ok(())
}
