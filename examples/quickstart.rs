//! Quickstart: load the tiny MoE model through the PJRT runtime and
//! generate text with HOBBIT's full pipeline (dynamic mixed-precision
//! loading + adaptive prefetching + multidimensional caching).
//!
//! Build artifacts first: `make artifacts`. Then:
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use hobbit::baselines;
use hobbit::config::HardwareConfig;
use hobbit::coordinator::{Coordinator, Request};
use hobbit::engine::Engine;

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "artifacts".into()),
    );

    // An RTX-4090-like offloading profile, scaled to the tiny model:
    // the expert cache holds 20 of 64 high-precision experts and loading
    // runs at a PCIe-like (scaled) 1.5 GB/s.
    let opts = baselines::real_hobbit(HardwareConfig::rtx4090_real());
    println!("loading mixtral-tiny ...");
    let engine = Engine::new(&artifacts, "mixtral-tiny", opts)?;
    println!(
        "model: {} layers x {} experts (top-{}), platform: {}",
        engine.cfg.n_layers,
        engine.cfg.n_experts,
        engine.cfg.top_k,
        engine.platform()
    );

    let mut coord = Coordinator::new(engine);
    let req = Request {
        id: 1,
        prompt: "Mixture-of-experts models activate only a few experts per token".into(),
        max_new_tokens: 48,
        temperature: 0.9,
    };
    let r = coord.generate(&req)?;

    println!("\ngenerated ({} tokens): {:?}", r.tokens.len(), r.text);
    println!(
        "\nprefill latency : {:.3} s\ndecode speed    : {:.2} tok/s\ncompute time    : {:.3} s\nload-wait time  : {:.3} s",
        r.metrics.prefill_time.as_secs_f64(),
        r.metrics.decode_tps(),
        r.metrics.compute_time.as_secs_f64(),
        r.metrics.load_wait_time.as_secs_f64(),
    );
    coord.sync_report();
    let st = &coord.report.loader;
    println!(
        "loader          : {} hi + {} lo on-demand loads, {} prefetches, {} skipped, {:.1} MB moved",
        st.ondemand_loads[0],
        st.ondemand_loads[1],
        st.prefetch_loads.iter().sum::<u64>(),
        st.skipped,
        st.bytes_loaded as f64 / 1e6
    );
    println!(
        "cache           : hit ratio {:.1}%, miss penalty {:.1}",
        100.0 * coord.report.cache.hit_ratio(),
        coord.report.cache.miss_penalty
    );
    Ok(())
}
