//! END-TO-END serving driver (the EXPERIMENTS.md validation run): load
//! the tiny MoE model, serve batched requests over the real TCP front-end
//! under an offloading-constrained hardware profile, and report prefill
//! latency + decode throughput per length group — the paper's §5.1
//! protocol (batch 1, groups [16,32] [16,128] [128,32] [128,128]) at
//! reproduction scale.
//!
//! ```sh
//! cargo run --release --example serve_offload -- [artifacts] [model] [hardware]
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use hobbit::baselines;
use hobbit::config::HardwareConfig;
use hobbit::coordinator::Coordinator;
use hobbit::engine::Engine;
use hobbit::server::Server;
use hobbit::util::json::Json;
use hobbit::util::rng::Rng;

/// The paper's four [input_len, output_len] groups, shortened for the
/// tiny testbed (prompt bytes -> roughly the target token counts).
const GROUPS: [(usize, usize); 4] = [(16, 32), (16, 128), (128, 32), (128, 128)];
const REQUESTS_PER_GROUP: usize = 3;

fn synth_prompt(rng: &mut Rng, len: usize) -> String {
    const WORDS: [&str; 12] = [
        "expert", "router", "cache", "token", "layer", "gate", "moe", "edge",
        "memory", "load", "tensor", "batch",
    ];
    let mut s = String::new();
    while s.len() < len {
        if !s.is_empty() {
            s.push(' ');
        }
        s.push_str(WORDS[rng.below(WORDS.len())]);
    }
    s.truncate(len);
    s
}

fn main() -> anyhow::Result<()> {
    let mut args = std::env::args().skip(1);
    let artifacts = std::path::PathBuf::from(args.next().unwrap_or_else(|| "artifacts".into()));
    let model = args.next().unwrap_or_else(|| "mixtral-tiny".into());
    let hw_name = args.next().unwrap_or_else(|| "rtx4090".into());
    let hw = HardwareConfig::preset(&hw_name).expect("hardware preset");

    println!("== HOBBIT end-to-end serving driver ==");
    println!("model={model} hardware={hw_name} (bw {:.2} GB/s, hi cache {} experts)",
        hw.load_bw / 1e9, hw.hi_cache_experts);

    let engine = Engine::new(&artifacts, &model, baselines::real_hobbit(hw))?;
    let mut coord = Coordinator::new(engine);
    let mut server = Server::bind("127.0.0.1:0")?;
    let addr = server.local_addr()?.to_string();
    println!("serving on {addr}\n");

    let total_conns = GROUPS.len() * REQUESTS_PER_GROUP;
    let client = std::thread::spawn(move || -> anyhow::Result<Vec<(usize, usize, Json)>> {
        let mut rng = Rng::new(0xE2E);
        let mut out = Vec::new();
        for (inp, gen) in GROUPS {
            for _ in 0..REQUESTS_PER_GROUP {
                let prompt = synth_prompt(&mut rng, inp);
                let mut stream = TcpStream::connect(&addr)?;
                writeln!(stream, "GEN {gen} 0.8 {prompt}")?;
                stream.flush()?;
                let mut reader = BufReader::new(stream);
                let mut line = String::new();
                reader.read_line(&mut line)?;
                let j = Json::parse(line.trim_end()).map_err(anyhow::Error::msg)?;
                out.push((inp, gen, j));
            }
        }
        Ok(out)
    });

    server.serve(&mut coord, Some(total_conns))?;
    let results = client.join().unwrap()?;

    println!("{:<14} {:>10} {:>14} {:>12}", "group", "requests", "prefill(s)", "decode tok/s");
    println!("{}", "-".repeat(56));
    for (inp, gen) in GROUPS {
        let rows: Vec<&Json> = results
            .iter()
            .filter(|(i, g, _)| *i == inp && *g == gen)
            .map(|(_, _, j)| j)
            .collect();
        let mean = |k: &str| {
            rows.iter().filter_map(|j| j.get(k).and_then(Json::as_f64)).sum::<f64>()
                / rows.len() as f64
        };
        println!(
            "[{inp:>3},{gen:>3}]     {:>10} {:>14.3} {:>12.2}",
            rows.len(),
            mean("prefill_s"),
            mean("decode_tps")
        );
    }

    coord.sync_report();
    let rep = &coord.report;
    println!("\ncache hit ratio {:.1}% | miss penalty {:.1} | {:.1} MB loaded | prefetch acc {:.0}%",
        100.0 * rep.cache.hit_ratio(),
        rep.cache.miss_penalty,
        rep.loader.bytes_loaded as f64 / 1e6,
        100.0 * rep.loader.prefetch_hits as f64 / rep.loader.prefetch_total.max(1) as f64,
    );
    println!("\nfull report JSON:\n{}", rep.to_json().to_string());
    Ok(())
}
