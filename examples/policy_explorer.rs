//! Policy explorer: compare cache replacement policies (the Fig 18 space)
//! on synthetic gating traces AND on the live engine, then sweep the
//! Eq. 3 weight blend to see the calibration surface.
//!
//! ```sh
//! cargo run --release --example policy_explorer
//! ```

use hobbit::cache::Policy;
use hobbit::trace::replay::{replay, ReplayConfig};
use hobbit::trace::{generate, TraceGenConfig};

fn main() {
    println!("== cache policy explorer ==\n");
    let gen = TraceGenConfig::mixtral_like();
    let traces = generate(&gen, 6, 96);
    let cfg = ReplayConfig { hi_capacity: 24, lo_capacity: 32, ..Default::default() };

    println!("{:<14} {:>10} {:>10} {:>12}", "policy", "hit%", "penalty", "vs random");
    println!("{}", "-".repeat(50));
    let base = replay(&traces, Policy::Random { seed: 3 }, &cfg).penalty;
    for (name, p) in [
        ("random", Policy::Random { seed: 3 }),
        ("lru", Policy::Lru),
        ("lfu-seq", Policy::LfuSeq),
        ("lfu-model", Policy::LfuModel),
        ("lhu", Policy::Lhu),
        ("fld", Policy::Fld),
        ("multidim", Policy::Multidim { w: [0.65, 0.05, 0.10, 0.20] }),
    ] {
        let r = replay(&traces, p, &cfg);
        println!(
            "{:<14} {:>9.1}% {:>10.1} {:>11.3}x",
            name,
            100.0 * r.hit_ratio(),
            r.penalty,
            r.penalty / base
        );
    }

    println!("\n== Eq. 3 weight sweep (lru, lfu, lhu, fld) ==\n");
    println!("{:<28} {:>10}", "weights", "penalty");
    println!("{}", "-".repeat(40));
    for w in [
        [1.0, 0.0, 0.0, 0.0],
        [0.0, 1.0, 0.0, 0.0],
        [0.0, 0.0, 1.0, 0.0],
        [0.0, 0.0, 0.0, 1.0],
        [0.25, 0.25, 0.25, 0.25],
        [0.65, 0.05, 0.10, 0.20],
        [0.5, 0.1, 0.2, 0.2],
        [0.4, 0.2, 0.2, 0.2],
    ] {
        let r = replay(&traces, Policy::Multidim { w }, &cfg);
        println!("{:<28} {:>10.1}", format!("{w:?}"), r.penalty);
    }

    println!("\n== cache-size sensitivity (multidim) ==\n");
    println!("{:<20} {:>10} {:>10}", "hi/lo capacity", "hit%", "penalty");
    println!("{}", "-".repeat(44));
    for (hi, lo) in [(8, 12), (16, 24), (24, 32), (43, 55), (64, 64)] {
        let c = ReplayConfig { hi_capacity: hi, lo_capacity: lo, ..Default::default() };
        let r = replay(&traces, Policy::Multidim { w: [0.65, 0.05, 0.10, 0.20] }, &c);
        println!("{:<20} {:>9.1}% {:>10.1}", format!("{hi}/{lo}"), 100.0 * r.hit_ratio(), r.penalty);
    }
}
