//! Loader/memory-hierarchy bench: transfer engine rates, task queue
//! round-trip latency, the scheduler's on-demand vs prefetch lane
//! behaviour under load (the Fig 6/9 machinery) — and the
//! **misprediction-penalty scenario**: an on-demand miss arriving just
//! behind a wrong, already-started prefetch, monolithic (the paper's
//! non-preemptible memcpy) vs the chunked preemptible pipeline.
//!
//! The misprediction scenario is artifact-free (synthesized expert
//! store), so it runs everywhere; pipeline counters are printed under a
//! `"serving"`-style side key — the FCFS `RunReport` JSON never carries
//! them.

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use hobbit::cache::{CacheManager, Policy, Pool};
use hobbit::config::{IoConfig, ModelConfig};
use hobbit::loader::{ExpertLoader, TaskKind};
use hobbit::memory::{LinkModel, ThrottledCopier};
use hobbit::model::synth::{tiny_store_config, write_synth_expert_store};
use hobbit::model::ExpertStore;
use hobbit::runtime::Manifest;
use hobbit::util::benchkit::{bench, header};
use hobbit::util::json::obj;
use hobbit::{ExpertKey, Precision};

// ---------------------------------------------------------------------
// Misprediction-penalty scenario (artifact-free, synthesized store)
// ---------------------------------------------------------------------

fn synth_store(cfg: &ModelConfig, dir: &Path) -> Arc<ExpertStore> {
    write_synth_expert_store(dir, cfg).expect("synth store");
    Arc::new(ExpertStore::load(dir, cfg).unwrap())
}

struct Rig {
    loader: ExpertLoader,
    copier: Arc<ThrottledCopier>,
}

fn mk_rig(bw: f64, io: IoConfig, name: &str) -> Rig {
    let cfg = tiny_store_config("bench-pipeline");
    let dir = std::env::temp_dir().join(format!("hobbit_bench_pipeline_{name}"));
    let store = synth_store(&cfg, &dir);
    let cache = Arc::new(Mutex::new(CacheManager::new(
        cfg.n_layers,
        cfg.n_experts,
        8,
        cfg.bytes_for(Precision::F32),
        8,
        cfg.bytes_for(Precision::Q8),
        Policy::Lru,
        0.25,
    )));
    let copier = Arc::new(ThrottledCopier::new(LinkModel { bytes_per_s: bw, latency_s: 0.0 }));
    let loader = ExpertLoader::start_with(store, cache, copier.clone(), io);
    Rig { loader, copier }
}

/// One run: a wrong prefetch starts, the on-demand miss lands mid-flight;
/// returns (miss time-to-ready, link drain wall time).
fn mispredict_once(rig: &Rig, transfer: Duration) -> (Duration, Duration) {
    let t_all = Instant::now();
    let pf = rig
        .loader
        .submit(ExpertKey::new(0, 0), Precision::F32, Pool::Hi, TaskKind::Prefetch, 0)
        .expect("prefetch");
    // the miss arrives ~15% into the prefetch transfer
    std::thread::sleep(transfer.mul_f64(0.15));
    let t0 = Instant::now();
    let od = rig
        .loader
        .submit(ExpertKey::new(1, 1), Precision::F32, Pool::Hi, TaskKind::OnDemand, 1)
        .expect("on-demand");
    rig.loader.wait(&[od]);
    let wait = t0.elapsed();
    rig.loader.wait(&[pf]);
    (wait, t_all.elapsed())
}

fn misprediction_scenario() {
    const BW: f64 = 1e5; // 4096-byte f32 record = ~41 ms on the link
    let transfer = Duration::from_secs_f64(4096.0 / BW);
    println!(
        "== misprediction penalty: on-demand miss behind a just-started wrong prefetch =="
    );
    let mono =
        mk_rig(BW, IoConfig { lanes: 1, chunk_bytes: usize::MAX, ..IoConfig::default() }, "mono");
    let pipe = mk_rig(BW, IoConfig { lanes: 1, chunk_bytes: 1024, ..IoConfig::default() }, "pipe");
    let (mono_wait, mono_drain) = mispredict_once(&mono, transfer);
    let (pipe_wait, pipe_drain) = mispredict_once(&pipe, transfer);
    let chunk_t = 1024.0 / BW;
    println!(
        "monolithic (non-preemptible)  miss ready in {:>6.1} ms   drain {:>6.1} ms",
        mono_wait.as_secs_f64() * 1e3,
        mono_drain.as_secs_f64() * 1e3,
    );
    println!(
        "chunked pipeline (1024 B)     miss ready in {:>6.1} ms   drain {:>6.1} ms",
        pipe_wait.as_secs_f64() * 1e3,
        pipe_drain.as_secs_f64() * 1e3,
    );
    let mono_stall = (mono_wait.as_secs_f64() - transfer.as_secs_f64()).max(1e-9);
    let pipe_stall = (pipe_wait.as_secs_f64() - transfer.as_secs_f64()).max(1e-9);
    println!(
        "stall behind the prefetch: {:.1} ms -> {:.1} ms ({:.1}x lower; one-chunk bound {:.1} ms)",
        mono_stall * 1e3,
        pipe_stall * 1e3,
        mono_stall / pipe_stall,
        chunk_t * 1e3,
    );
    println!(
        "bytes moved: monolithic {} / pipeline {} (bandwidth conserved)",
        mono.copier.bytes_moved(),
        pipe.copier.bytes_moved(),
    );
    // pipeline counters under the "serving"-style side key (the FCFS
    // RunReport JSON never carries these)
    let st = pipe.loader.stats.lock().unwrap().clone();
    println!("{}", obj(vec![("serving", st.pipeline_json())]).to_string());
    if pipe_stall * 4.0 > mono_stall {
        eprintln!("WARNING: chunked pipeline did not cut the misprediction stall >= 4x");
    }
    println!();
}

fn main() {
    header();

    misprediction_scenario();

    // raw throttled-copy rates at the modeled links
    for (label, bw) in [("16 GB/s", 16e9), ("1.5 GB/s", 1.5e9)] {
        let copier = ThrottledCopier::new(LinkModel { bytes_per_s: bw, latency_s: 0.0 });
        let src = vec![7u8; 1_572_864]; // one f32 tiny expert
        let mut dst = vec![0u8; src.len()];
        bench(&format!("throttled memcpy 1.5MB @ {label}"), || {
            let _ = copier.transfer(&src, &mut dst);
        });
    }

    let root = PathBuf::from("artifacts");
    if !root.join("mixtral-tiny/manifest.json").exists() {
        eprintln!("artifacts not built; skipping loader round-trip benches");
        return;
    }
    let manifest = Manifest::parse(
        &std::fs::read_to_string(root.join("mixtral-tiny/manifest.json")).unwrap(),
    )
    .unwrap();
    let cfg = ModelConfig::from_manifest(&manifest.model_json()).unwrap();
    let store =
        Arc::new(ExpertStore::load(&root.join("weights/mixtral-tiny"), &cfg).unwrap());

    // loader round-trip: submit -> lane thread -> commit -> wait
    let cache = Arc::new(Mutex::new(CacheManager::new(
        cfg.n_layers,
        cfg.n_experts,
        4,
        cfg.bytes_for(Precision::F32),
        4,
        cfg.bytes_for(Precision::Q8),
        Policy::Lru,
        0.25,
    )));
    let copier = Arc::new(ThrottledCopier::new(LinkModel { bytes_per_s: 64e9, latency_s: 0.0 }));
    let loader = ExpertLoader::start(store, cache, copier);
    let mut i = 0u32;
    bench("loader round-trip (submit+wait, 64GB/s link)", || {
        // rotate keys so every submit is a real (non-deduped) load
        let key = ExpertKey::new(i % cfg.n_layers, (i / cfg.n_layers) % cfg.n_experts);
        i += 1;
        if let Some(id) = loader.submit(key, Precision::Q8, Pool::Lo, TaskKind::OnDemand, 0) {
            loader.wait(&[id]);
        }
    });
}
