//! Loader/memory-hierarchy bench: transfer engine rates, task queue
//! round-trip latency, and the scheduler thread's on-demand vs prefetch
//! lane behaviour under load (the Fig 6/9 machinery).

use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use hobbit::cache::{CacheManager, Policy, Pool};
use hobbit::config::ModelConfig;
use hobbit::loader::{ExpertLoader, TaskKind};
use hobbit::memory::{LinkModel, ThrottledCopier};
use hobbit::model::ExpertStore;
use hobbit::runtime::Manifest;
use hobbit::util::benchkit::{bench, header};
use hobbit::{ExpertKey, Precision};

fn main() {
    header();

    // raw throttled-copy rates at the modeled links
    for (label, bw) in [("16 GB/s", 16e9), ("1.5 GB/s", 1.5e9)] {
        let copier = ThrottledCopier::new(LinkModel { bytes_per_s: bw, latency_s: 0.0 });
        let src = vec![7u8; 1_572_864]; // one f32 tiny expert
        let mut dst = vec![0u8; src.len()];
        bench(&format!("throttled memcpy 1.5MB @ {label}"), || {
            let _ = copier.transfer(&src, &mut dst);
        });
    }

    let root = PathBuf::from("artifacts");
    if !root.join("mixtral-tiny/manifest.json").exists() {
        eprintln!("artifacts not built; skipping loader round-trip benches");
        return;
    }
    let manifest = Manifest::parse(
        &std::fs::read_to_string(root.join("mixtral-tiny/manifest.json")).unwrap(),
    )
    .unwrap();
    let cfg = ModelConfig::from_manifest(&manifest.model_json()).unwrap();
    let store =
        Arc::new(ExpertStore::load(&root.join("weights/mixtral-tiny"), &cfg).unwrap());

    // loader round-trip: submit -> scheduler thread -> commit -> wait
    let cache = Arc::new(Mutex::new(CacheManager::new(
        cfg.n_layers,
        cfg.n_experts,
        4,
        cfg.bytes_for(Precision::F32),
        4,
        cfg.bytes_for(Precision::Q8),
        Policy::Lru,
        0.25,
    )));
    let copier = Arc::new(ThrottledCopier::new(LinkModel { bytes_per_s: 64e9, latency_s: 0.0 }));
    let loader = ExpertLoader::start(store, cache, copier);
    let mut i = 0u32;
    bench("loader round-trip (submit+wait, 64GB/s link)", || {
        // rotate keys so every submit is a real (non-deduped) load
        let key = ExpertKey::new(i % cfg.n_layers, (i / cfg.n_layers) % cfg.n_experts);
        i += 1;
        if let Some(id) = loader.submit(key, Precision::Q8, Pool::Lo, TaskKind::OnDemand, 0) {
            loader.wait(&[id]);
        }
    });
}
