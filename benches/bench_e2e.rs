//! Fig 14/15/16 bench: end-to-end decode throughput + prefill latency of
//! HOBBIT vs every baseline at paper scale (the DES), plus one live
//! tiny-model serving measurement per hardware profile (the real path).

use std::path::PathBuf;

use hobbit::baselines::{self, EQ3_WEIGHTS};
use hobbit::config::HardwareConfig;
use hobbit::coordinator::{Coordinator, Request};
use hobbit::engine::Engine;
use hobbit::sim::des::simulate_decode;
use hobbit::sim::params::{SimHardware, SimModel};
use hobbit::trace::{generate, TraceGenConfig};
use hobbit::util::benchkit::{bench_cfg, header, BenchConfig};

fn main() {
    println!("== sim @ paper scale: decode tok/s (prefill s) ==\n");
    for (gname, hw, systems) in [
        ("orin-int8", SimHardware::orin(), baselines::group_orin_int8()),
        ("4090-f16", SimHardware::rtx4090(), baselines::group_rtx4090_f16()),
        ("4090+cpu", SimHardware::rtx4090(), baselines::group_rtx4090_cpu()),
    ] {
        for model in [SimModel::mixtral_8x7b(), SimModel::phi_moe()] {
            let gen = if model.n_experts == 16 {
                TraceGenConfig::phi_like()
            } else {
                TraceGenConfig::mixtral_like()
            };
            let traces = generate(&gen, 2, 64);
            print!("{gname:<10} {:<14}", model.name);
            for sys in &systems {
                let (p, d) = simulate_decode(sys, &hw, &model, &traces, 16, 1);
                print!(" {}={:.2}t/s({:.2}s)", sys.name, d.tps(), p.latency);
            }
            println!();
        }
    }

    // ablation: dynamic loading on/off (Fig 16)
    println!("\n== Fig 16 ablation (sim): dynamic loading speedup ==");
    for model in [SimModel::mixtral_8x7b(), SimModel::phi_moe()] {
        let traces = generate(&TraceGenConfig::mixtral_like(), 2, 64);
        let hw = SimHardware::orin();
        let on = simulate_decode(&hobbit::sim::des::SimSystem::hobbit_int8(EQ3_WEIGHTS), &hw, &model, &traces, 16, 1).1;
        let mut sys_off = hobbit::sim::des::SimSystem::hobbit_int8(EQ3_WEIGHTS);
        sys_off.dynamic = false;
        sys_off.lo_cache_frac = 0.0;
        let off = simulate_decode(&sys_off, &hw, &model, &traces, 16, 1).1;
        println!("  {}: {:.2}x", model.name, on.tps() / off.tps());
    }

    // live tiny-model serving (real path)
    let artifacts = PathBuf::from("artifacts");
    if !artifacts.join("mixtral-tiny/manifest.json").exists() {
        eprintln!("\n(artifacts not built; skipping live benches)");
        return;
    }
    println!("\n== live tiny-model serving (PJRT real path) ==\n");
    header();
    for hw_name in ["rtx4090", "orin"] {
        let hw = HardwareConfig::preset(hw_name).unwrap();
        let engine =
            Engine::new(&artifacts, "mixtral-tiny", baselines::real_hobbit(hw)).unwrap();
        let mut coord = Coordinator::new(engine);
        let mut n = 0u64;
        bench_cfg(
            &format!("live generate [16 in, 8 out] @ {hw_name}"),
            BenchConfig { warmup_iters: 1, min_iters: 3, max_iters: 5, min_time_s: 0.0 },
            || {
                n += 1;
                let _ = coord
                    .generate(&Request::new(n, "sixteen byte pro", 8))
                    .unwrap();
            },
        );
        coord.sync_report();
        println!(
            "   -> mean decode {:.2} tok/s, prefill {:.3} s, hit ratio {:.1}%",
            coord.report.mean_decode_tps(),
            coord.report.mean_prefill_s(),
            100.0 * coord.report.cache.hit_ratio()
        );
    }
}
