//! Serving-discipline bench: batch-1 blocking FCFS vs the interleaved
//! scheduler on the same request set under an *offload-bound* config
//! (slow expert link + small cache, so decode stalls on on-demand
//! transfers). Reports aggregate decode tok/s for both and the
//! overlap-ratio metric (fraction of load stall hidden by other
//! sequences' compute) for the interleaved run.

use std::path::PathBuf;
use std::time::Instant;

use hobbit::baselines;
use hobbit::config::HardwareConfig;
use hobbit::coordinator::{Coordinator, Request, SchedulerMode};
use hobbit::engine::Engine;
use hobbit::metrics::RunReport;

/// Slow link + tiny cache: the regime where expert loading dominates
/// decode (Fig 3a) and blocking FCFS leaves the engine idle.
fn offload_hw() -> HardwareConfig {
    HardwareConfig {
        name: "bench-offload".into(),
        load_bw: 3e8,
        load_latency: 0.0,
        hi_cache_experts: 8,
        lo_cache_experts: 12,
        cpu_assist: false,
        cpu_expert_time: 0.0,
    }
}

const PROMPTS: [&str; 6] = [
    "the mixture of experts model",
    "edge serving under memory pressure",
    "expert caches and replacement policy",
    "token level dynamic precision loading",
    "prefetching hides transfer latency",
    "interleaved scheduling of sequences",
];
const MAX_NEW: usize = 12;

fn run(mode: SchedulerMode) -> (f64, usize, RunReport) {
    let engine = Engine::new(
        &PathBuf::from("artifacts"),
        "mixtral-tiny",
        baselines::real_hobbit(offload_hw()),
    )
    .expect("engine");
    let mut coord = Coordinator::new(engine);
    coord.mode = mode;
    for (i, p) in PROMPTS.iter().enumerate() {
        coord.submit(Request::new(i as u64 + 1, *p, MAX_NEW));
    }
    let t0 = Instant::now();
    let results = coord.drain().expect("drain");
    let wall = t0.elapsed().as_secs_f64();
    let tokens: usize = results.iter().map(|r| r.tokens.len()).sum();
    coord.sync_report();
    (wall, tokens, coord.report.clone())
}

fn main() {
    if !PathBuf::from("artifacts/mixtral-tiny/manifest.json").exists() {
        eprintln!("artifacts not built; skipping serving bench");
        return;
    }
    println!(
        "== serving bench: {} requests x {} tokens, offload-bound ({} GB/s, hi cache {}) ==\n",
        PROMPTS.len(),
        MAX_NEW,
        offload_hw().load_bw / 1e9,
        offload_hw().hi_cache_experts,
    );

    let (fcfs_wall, fcfs_tokens, _) = run(SchedulerMode::Fcfs);
    let fcfs_tps = fcfs_tokens as f64 / fcfs_wall;
    println!(
        "fcfs         {fcfs_tokens:>4} tok in {fcfs_wall:>6.2}s  -> {fcfs_tps:>6.2} tok/s aggregate"
    );

    let (il_wall, il_tokens, rep) = run(SchedulerMode::Interleaved);
    let il_tps = il_tokens as f64 / il_wall;
    println!(
        "interleaved  {il_tokens:>4} tok in {il_wall:>6.2}s  -> {il_tps:>6.2} tok/s aggregate"
    );

    let sch = rep.scheduler.clone().expect("interleaved run reports scheduler stats");
    println!(
        "\nspeedup {:.2}x | overlap ratio {:.2} | stall {:.2}s total, {:.2}s unhidden | mean ttft {:.3}s | mean queue wait {:.3}s",
        il_tps / fcfs_tps,
        sch.overlap_ratio(),
        sch.total_stall.as_secs_f64(),
        sch.unhidden_stall.as_secs_f64(),
        sch.mean_ttft_s(),
        sch.mean_queue_wait_s(),
    );
    println!(
        "cross-sequence load dedup: {} of {} on-demand requests joined an in-flight transfer",
        rep.loader.dedup_hits, rep.loader.dedup_total,
    );
    if il_tps <= fcfs_tps {
        eprintln!("WARNING: interleaved did not beat FCFS on this host/config");
    }
    if sch.overlap_ratio() <= 0.0 {
        eprintln!("WARNING: no load stall was hidden (overlap ratio 0)");
    }
}
