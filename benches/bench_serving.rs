//! Serving-discipline bench: batch-1 blocking FCFS vs the interleaved
//! scheduler on the same request set under an *offload-bound* config
//! (slow expert link + small cache, so decode stalls on on-demand
//! transfers). Reports aggregate decode tok/s for both and the
//! overlap-ratio metric (fraction of load stall hidden by other
//! sequences' compute) for the interleaved run.
//!
//! Also runs the **late long-prompt admission** scenario (artifact-free,
//! on the reference executor + synthesized model): live sequences decode
//! steadily while a 300-token prompt is admitted, blocking vs chunked.
//! Blocking admission inserts the whole prefill into every live
//! sequence's inter-token gap; the chunked `PrefillCursor` bounds that
//! gap by ~one chunk's work. The p50/p99/max inter-token latencies of
//! the live sequences during the admission window quantify it (the DES
//! mirror is `sim::des::simulate_admission`).
//!
//! The **ragged grouped decode** scenario (artifact-free) A/Bs grouped
//! execution against the legacy per-row path at batch {4, 16, 64} on a
//! hot-skewed request set: per-step launch/dequant counts show launches
//! collapsing to O(unique experts) while `dequant_reuses` and the
//! hot-expert replica counters absorb the row fan-in (the DES mirror is
//! `sim::des::simulate_grouped_decode`).
//!
//! And the **remote expert tier** scenario (also artifact-free): a real
//! in-process shard server owning half the synthetic store's experts,
//! fetched through the `TieredStore` over the modeled network link class
//! — local-DRAM vs cold-peer vs staged sweeps, the remote counters the
//! serving report surfaces, and the N nodes x M users DES sweep
//! (`sim::des::simulate_remote_cluster`).

use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use hobbit::baselines;
use hobbit::cache::{CacheManager, Policy, Pool};
use hobbit::config::{HardwareConfig, IoConfig, PolicyConfig};
use hobbit::coordinator::{Coordinator, Request, SchedulerMode};
use hobbit::engine::{Engine, EngineOptions, KvState, PrefillProgress};
use hobbit::loader::scorer::Class;
use hobbit::memory::{LinkModel, ThrottledCopier};
use hobbit::metrics::RunReport;
use hobbit::model::synth::{
    tiny_model_config, tiny_store_config, write_synth_expert_store, write_synth_model,
};
use hobbit::model::ExpertStore;
use hobbit::predictor::{AccuracyTracker, Predictor};
use hobbit::residency::ExpertResidency;
use hobbit::sim::des::{simulate_open_loop, simulate_progressive_fetch};
use hobbit::tokenizer::BOS;
use hobbit::trace::replay::{replay, ReplayConfig};
use hobbit::trace::{generate, TraceGenConfig};
use hobbit::util::stats::summarize;
use hobbit::workload::{self, DriveOptions, WorkloadConfig};
use hobbit::{ExpertKey, Precision};

/// Slow link + tiny cache: the regime where expert loading dominates
/// decode (Fig 3a) and blocking FCFS leaves the engine idle.
fn offload_hw() -> HardwareConfig {
    HardwareConfig {
        name: "bench-offload".into(),
        load_bw: 3e8,
        load_latency: 0.0,
        hi_cache_experts: 8,
        lo_cache_experts: 12,
        cpu_assist: false,
        cpu_expert_time: 0.0,
    }
}

const PROMPTS: [&str; 6] = [
    "the mixture of experts model",
    "edge serving under memory pressure",
    "expert caches and replacement policy",
    "token level dynamic precision loading",
    "prefetching hides transfer latency",
    "interleaved scheduling of sequences",
];
const MAX_NEW: usize = 12;

fn run(mode: SchedulerMode) -> (f64, usize, RunReport) {
    let engine = Engine::new(
        &PathBuf::from("artifacts"),
        "mixtral-tiny",
        baselines::real_hobbit(offload_hw()),
    )
    .expect("engine");
    let mut coord = Coordinator::new(engine);
    coord.mode = mode;
    for (i, p) in PROMPTS.iter().enumerate() {
        coord.submit(Request::new(i as u64 + 1, *p, MAX_NEW));
    }
    let t0 = Instant::now();
    let results = coord.drain().expect("drain");
    let wall = t0.elapsed().as_secs_f64();
    let tokens: usize = results.iter().map(|r| r.tokens.len()).sum();
    coord.sync_report();
    (wall, tokens, coord.report.clone())
}

// ---------------------------------------------------------------------
// Late long-prompt admission (artifact-free, reference executor)
// ---------------------------------------------------------------------

const ADMIT_LIVE: usize = 3;
const ADMIT_PROMPT: usize = 300;

/// Offload-bound reference engine over a synthesized model: ~3 ms per
/// f32 expert on the link, a cache smaller than the working set, dynamic
/// loading off (logits stay bit-identical whichever admission path runs).
fn admission_engine(tag: &str) -> Engine {
    let dir = std::env::temp_dir().join(format!("hobbit_bench_admit_{tag}"));
    let mut cfg = tiny_model_config("bench-admit");
    cfg.max_seq = 512;
    write_synth_model(&dir, &cfg, 0xBE7C4).expect("synth model");
    let hw = HardwareConfig {
        name: "bench-admit".into(),
        load_bw: 2e6,
        load_latency: 0.0,
        hi_cache_experts: 6,
        lo_cache_experts: 6,
        cpu_assist: false,
        cpu_expert_time: 0.0,
    };
    let policy =
        PolicyConfig { dynamic_loading: false, prefetch_depth: 2, ..PolicyConfig::default() };
    Engine::new_reference(&dir, cfg, EngineOptions::new(hw, policy))
        .expect("reference engine")
}

fn admit_token(row: usize, step: usize) -> u32 {
    (65 + ((row * 31 + step * 7) % 190)) as u32
}

/// Decode one token on every live sequence; records each sequence's
/// inter-token gap into `gaps` when `record` is set.
#[allow(clippy::too_many_arguments)]
fn decode_round(
    eng: &mut Engine,
    kvs: &mut [KvState],
    steps: &mut [usize],
    last: &mut [Instant],
    gaps: &mut Vec<f64>,
    record: bool,
) {
    for r in 0..kvs.len() {
        let t = admit_token(r, steps[r]);
        let _ = eng.decode_step(&mut kvs[r], t).expect("decode");
        steps[r] += 1;
        if record {
            gaps.push(last[r].elapsed().as_secs_f64());
        }
        last[r] = Instant::now();
    }
}

/// Run the scenario once: warm live decode, admit a 300-token prompt
/// (blocking or chunked), keep decoding. Returns the live sequences'
/// inter-token gaps over the admission window (+2 settle rounds) and the
/// admission's wall latency.
fn late_admission(chunked: bool) -> (Vec<f64>, f64) {
    let mut eng = admission_engine(if chunked { "chunked" } else { "blocking" });
    let mut kvs: Vec<KvState> = Vec::with_capacity(ADMIT_LIVE);
    for r in 0..ADMIT_LIVE {
        let mut kv = eng.new_sequence();
        eng.prefill(&mut kv, &[BOS, 70 + r as u32]).expect("live prefill");
        kvs.push(kv);
    }
    let mut steps = vec![0usize; ADMIT_LIVE];
    let mut last = vec![Instant::now(); ADMIT_LIVE];
    let mut gaps: Vec<f64> = Vec::new();
    // steady state before the admission
    for _ in 0..3 {
        decode_round(&mut eng, &mut kvs, &mut steps, &mut last, &mut gaps, false);
    }

    let long_prompt: Vec<u32> = (0..ADMIT_PROMPT as u32)
        .map(|i| 65 + (i * 13) % 190)
        .collect();
    let mut kv_new = eng.new_sequence();
    let t_admit = Instant::now();
    if chunked {
        // the interleaved scheduler's shape: one chunk per slice, live
        // decode between slices, park-resolution when loads lag
        let mut cur = eng.prefill_begin(&kv_new, &long_prompt).expect("prefill begin");
        loop {
            match eng.prefill_poll(&mut kv_new, &mut cur).expect("prefill poll") {
                PrefillProgress::Done(_) => break,
                PrefillProgress::Chunk { .. } | PrefillProgress::Pending => {
                    if steps[0] < 400 {
                        decode_round(
                            &mut eng, &mut kvs, &mut steps, &mut last, &mut gaps, true,
                        );
                    } else {
                        // KV safety valve (never hit in practice)
                        eng.prefill_block(&mut cur);
                    }
                }
            }
        }
    } else {
        // blocking admission: live decode sits idle for the whole prefill
        let _ = eng.prefill(&mut kv_new, &long_prompt).expect("prefill");
    }
    let admit_wall = t_admit.elapsed().as_secs_f64();
    // settle rounds: the blocking variant's stall lands in these gaps
    for _ in 0..2 {
        decode_round(&mut eng, &mut kvs, &mut steps, &mut last, &mut gaps, true);
    }
    (gaps, admit_wall)
}

fn admission_scenario() {
    println!(
        "== late long-prompt admission: {ADMIT_LIVE} live seqs, {ADMIT_PROMPT}-token \
         prompt, reference executor ==\n"
    );
    let (bg, bw) = late_admission(false);
    let (cg, cw) = late_admission(true);
    let bs = summarize(&bg);
    let cs = summarize(&cg);
    println!(
        "blocking  admission {bw:>6.2}s | live inter-token p50 {:>7.1}ms  p99 {:>7.1}ms  \
         max {:>7.1}ms",
        bs.p50 * 1e3,
        bs.p99 * 1e3,
        bs.max * 1e3,
    );
    println!(
        "chunked   admission {cw:>6.2}s | live inter-token p50 {:>7.1}ms  p99 {:>7.1}ms  \
         max {:>7.1}ms",
        cs.p50 * 1e3,
        cs.p99 * 1e3,
        cs.max * 1e3,
    );
    if cs.max > 0.0 {
        println!(
            "\ndecode stall bound during admission: {:.1}x lower p99, {:.1}x lower max \
             (O(full prefill) -> O(one chunk))",
            bs.p99 / cs.p99.max(1e-9),
            bs.max / cs.max.max(1e-9),
        );
    }
    if bs.max <= cs.max {
        eprintln!("WARNING: chunked admission did not reduce the worst live-seq gap");
    }
}

// ---------------------------------------------------------------------
// Accuracy-vs-latency: the progressive precision-floor sweep
// (artifact-free: real residency/loader/link over a synthetic store)
// ---------------------------------------------------------------------

/// Slow enough (~20 ms per f32 expert) that the per-precision transfer
/// time dominates the measured acquire wall time.
const FLOOR_BW: f64 = 2e5;

/// Measured time-to-first-usable of a cold on-demand miss with the fetch
/// floor pinned to `pin`: one acquire per expert of the tiny synthetic
/// store, averaged. The residency facade, loader lanes, and throttled
/// link are the real ones.
fn measured_ttfu(pin: Precision) -> f64 {
    let cfg = tiny_store_config("bench-floor");
    let dir = std::env::temp_dir().join(format!("hobbit_bench_floor_{}", pin.name()));
    write_synth_expert_store(&dir, &cfg).expect("synth store");
    let store = Arc::new(ExpertStore::load(&dir, &cfg).expect("store"));
    let cache = Arc::new(Mutex::new(CacheManager::new(
        cfg.n_layers,
        cfg.n_experts,
        16,
        cfg.bytes_for(Precision::F32),
        4,
        cfg.bytes_for(Precision::Q8),
        Policy::Lru,
        0.25,
    )));
    let copier =
        Arc::new(ThrottledCopier::new(LinkModel { bytes_per_s: FLOOR_BW, latency_s: 0.0 }));
    let predictor = Predictor::new(2, cfg.top_k, 0.6, 0.9, true, cfg.n_layers);
    let resid = ExpertResidency::with_io(
        store,
        cache,
        copier,
        predictor,
        Precision::F32,
        Precision::Q8,
        IoConfig { lanes: 2, chunk_bytes: 1024, ..IoConfig::default() },
    )
    .with_precision_mode(Some(pin), false, 0.6);
    let mut total = 0.0;
    let mut n = 0u32;
    for layer in 0..cfg.n_layers {
        for expert in 0..cfg.n_experts {
            let key = ExpertKey::new(layer, expert);
            let t0 = Instant::now();
            let (_u, w) = resid.acquire(layer, vec![(key, Class::Hi, vec![1.0], 1.0)], None);
            resid.wait(&w);
            total += t0.elapsed().as_secs_f64();
            resid.release(key, Pool::Hi);
            n += 1;
        }
    }
    total / n as f64
}

/// Next-layer top-k gate prediction accuracy over the trace (the quality
/// signal the prefetcher rides; `AccuracyTracker` is the engine's own
/// Fig 7b tracker).
fn gate_prediction_accuracy(ts: &hobbit::trace::TraceSet, k: usize) -> f64 {
    let mut tracker = AccuracyTracker::new(1);
    for s in &ts.seqs {
        for t in 0..s.n_tokens {
            for l in 0..s.n_layers.saturating_sub(1) {
                let cur: Vec<u32> =
                    s.event(t, l).top_k(k).iter().map(|x| x.0 as u32).collect();
                let nxt: Vec<u32> =
                    s.event(t, l + 1).top_k(k).iter().map(|x| x.0 as u32).collect();
                tracker.record(1, &cur, &nxt);
            }
        }
    }
    tracker.accuracy(1)
}

/// For each candidate fetch floor: measured TTFU (pinned acquire), the
/// DES model's TTFU for the same staged lo->hi stream, and the cache
/// replay's miss penalty when a lo miss costs `bytes(p)/bytes(f32)`.
/// Quantifies the accuracy-vs-latency trade progressive streaming
/// schedules over. Counters surface under the report's "serving" key
/// only — the FCFS RunReport stays byte-stable.
fn progressive_floor_scenario() {
    let cfg = tiny_store_config("bench-floor");
    let hi_bytes = cfg.bytes_for(Precision::F32) as f64;
    println!(
        "\n== progressive floor sweep: accuracy vs time-to-first-usable \
         ({:.0} KB/s link, {} B f32 record) ==\n",
        FLOOR_BW / 1e3,
        hi_bytes,
    );
    let ts = generate(
        &TraceGenConfig { n_layers: 8, n_experts: 8, ..TraceGenConfig::mixtral_like() },
        4,
        48,
    );
    let gate_acc = gate_prediction_accuracy(&ts, 2);
    let mut rows: Vec<String> = Vec::new();
    let mut ttfus: Vec<(Precision, f64)> = Vec::new();
    for p in Precision::ALL {
        let ttfu = measured_ttfu(p);
        ttfus.push((p, ttfu));
        let model = simulate_progressive_fetch(
            FLOOR_BW,
            0.0,
            cfg.bytes_for(p) as f64,
            hi_bytes,
            1024.0,
            false,
        );
        let rep = replay(
            &ts,
            Policy::Multidim { w: [0.65, 0.05, 0.10, 0.20] },
            &ReplayConfig {
                penalty_ratio: cfg.bytes_for(p) as f64 / hi_bytes,
                ..ReplayConfig::default()
            },
        );
        println!(
            "{:>4}  ttfu {:>7.2}ms (model {:>7.2}ms) | replay miss penalty {:>7.2}, \
             hit ratio {:.3}",
            p.name(),
            ttfu * 1e3,
            model.time_to_first_usable * 1e3,
            rep.penalty,
            rep.hit_ratio(),
        );
        rows.push(format!(
            "{{\"precision\":\"{}\",\"ttfu_ms\":{:.3},\"model_ttfu_ms\":{:.3},\
             \"miss_penalty\":{:.2},\"hit_ratio\":{:.4}}}",
            p.name(),
            ttfu * 1e3,
            model.time_to_first_usable * 1e3,
            rep.penalty,
            rep.hit_ratio(),
        ));
    }
    let floor_ttfu = |p: Precision| {
        ttfus.iter().find(|(q, _)| *q == p).map(|(_, t)| *t).unwrap_or(0.0)
    };
    let f32_ttfu = floor_ttfu(Precision::F32);
    let q4_ttfu = floor_ttfu(Precision::Q4);
    println!(
        "\ngate top-2 next-layer prediction accuracy {gate_acc:.3} | \
         q4 floor cuts first-usable {:.1}x vs hi-only",
        f32_ttfu / q4_ttfu.max(1e-9),
    );
    // the same counters the server emits — "serving" key only
    println!(
        "serving: {{\"progressive_floor\":[{}],\"gate_top2_accuracy\":{gate_acc:.4}}}",
        rows.join(","),
    );
    if q4_ttfu >= f32_ttfu {
        eprintln!("WARNING: a narrower floor did not reduce time-to-first-usable");
    }
}

// ---------------------------------------------------------------------
// Open-loop overload: the traffic harness + degradation ladder A/B
// (artifact-free: reference executor, real scheduler, real trace replay)
// ---------------------------------------------------------------------

/// Offload-bound reference engine with progressive streaming on — the
/// precision stage of the ladder has a lo tier to shed to.
fn overload_engine(tag: &str) -> Engine {
    let dir = std::env::temp_dir().join(format!("hobbit_bench_openloop_{tag}"));
    let mut cfg = tiny_model_config("bench-openloop");
    cfg.max_seq = 512;
    write_synth_model(&dir, &cfg, 0x0BE7_10AD).expect("synth model");
    let hw = HardwareConfig {
        name: "bench-openloop".into(),
        load_bw: 2e6,
        load_latency: 0.0,
        hi_cache_experts: 6,
        lo_cache_experts: 6,
        cpu_assist: false,
        cpu_expert_time: 0.0,
    };
    let policy = PolicyConfig { progressive: true, prefetch_depth: 2, ..PolicyConfig::default() };
    Engine::new_reference(&dir, cfg, EngineOptions::new(hw, policy))
        .expect("reference engine")
}

/// The bursty open-loop trace both A/B runs replay (same seed → byte-
/// identical offered load for ladder-on and ladder-off).
fn overload_trace_cfg() -> WorkloadConfig {
    WorkloadConfig {
        mean_rps: 40.0,
        burstiness: 0.4,
        diurnal_period_s: 2.0,
        duration_s: 2.0,
        prompt_mean: 8.0,
        prompt_sigma: 0.5,
        prompt_max: 32,
        output_mean: 4.0,
        output_sigma: 0.4,
        output_max: 16,
        seed: 0x0B5E55ED,
    }
}

/// One measured open-loop replay: fresh engine, bounded admission queue,
/// the ladder on or off. Returns (goodput, rejected) and prints the tail
/// row + (for the ladder run) the serving JSON section.
fn open_loop_run(ladder: bool) -> (f64, usize) {
    let eng = overload_engine(if ladder { "ladder" } else { "noladder" });
    let mut coord = Coordinator::interleaved(eng);
    coord.max_active = 2;
    coord.overload.queue_limit = Some(4);
    coord.overload.slo_ttft = Some(Duration::from_millis(750));
    coord.overload.ladder = ladder;
    let trace = workload::generate_trace(&overload_trace_cfg());
    let opts = DriveOptions { max_wall: Duration::from_secs(120), ..Default::default() };
    let rep = workload::drive(&mut coord, &trace, &opts).expect("open-loop drive");
    let sch = coord.scheduler_stats();
    println!(
        "{:<9} ttft p50 {:>6.1}ms p99 {:>7.1}ms p99.9 {:>7.1}ms | itl p99 {:>6.1}ms | \
         goodput {:>6.2} tok/s, slo {:.2} | admitted {:>3}, rejected {:>3}, shed rounds {:>4}",
        if ladder { "ladder" } else { "no-ladder" },
        sch.ttft_hist.p50_s() * 1e3,
        sch.ttft_hist.p99_s() * 1e3,
        sch.ttft_hist.p999_s() * 1e3,
        sch.itl_hist.p99_s() * 1e3,
        sch.goodput_tps(),
        sch.slo_attainment(),
        rep.submitted,
        rep.rejected,
        sch.shed_precision_rounds,
    );
    if rep.hit_wall {
        eprintln!("WARNING: open-loop replay hit the wall-clock bound");
    }
    let goodput = sch.goodput_tps();
    if ladder {
        // the same counters `hobbit serve` emits — "serving" key only
        if let Some(serving) = coord.report.to_json().get("serving") {
            println!("serving: {serving}");
        }
    }
    (goodput, rep.rejected)
}

/// Open-loop overload A/B (measured) + the deterministic DES sweep of the
/// same ladder (`sim::des::simulate_open_loop`) across overload factors —
/// the acceptance demonstration that shedding precision first holds
/// goodput where the rigid baseline sheds requests.
fn open_loop_scenario() {
    let cfg = overload_trace_cfg();
    println!(
        "\n== open-loop overload: {:.0} rps offered for {:.0}s (burstiness {:.1}), \
         queue bound 4, reference executor ==\n",
        cfg.mean_rps, cfg.duration_s, cfg.burstiness,
    );
    let (ladder_good, _) = open_loop_run(true);
    let (base_good, _) = open_loop_run(false);
    if base_good > 0.0 {
        println!(
            "\nmeasured goodput under overload: ladder {:.2}x the no-ladder baseline",
            ladder_good / base_good,
        );
    }
    if ladder_good < base_good {
        eprintln!("WARNING: the ladder lost goodput vs the no-ladder baseline");
    }

    // the deterministic twin: same trace generator, closed-form service.
    // tau_hi/tau_lo mirror the f32-vs-q8 byte ratio on the modeled link;
    // mean_rps is scaled so `x` is the offered/capacity ratio.
    let (tau_hi, tau_lo, prefill_tok) = (4e-3, 1e-3, 2e-4);
    println!("\n== DES open-loop sweep: goodput vs overload factor (queue 32, slo 0.5s) ==\n");
    for x in [0.5f64, 1.0, 2.0, 4.0] {
        let service = 32.0 * prefill_tok + 16.0 * tau_hi;
        let des_cfg = WorkloadConfig {
            mean_rps: x / service,
            burstiness: 0.3,
            diurnal_period_s: 20.0,
            duration_s: 60.0,
            prompt_mean: 32.0,
            prompt_sigma: 0.4,
            prompt_max: 128,
            output_mean: 16.0,
            output_sigma: 0.3,
            output_max: 64,
            seed: 0xde5_10ad,
        };
        let on = simulate_open_loop(&des_cfg, 32, 0.25, true, tau_hi, tau_lo, prefill_tok, 0.5);
        let off =
            simulate_open_loop(&des_cfg, 32, 0.25, false, tau_hi, tau_lo, prefill_tok, 0.5);
        let ratio =
            if off.goodput_tps > 0.0 { on.goodput_tps / off.goodput_tps } else { f64::INFINITY };
        println!(
            "{x:>3.1}x offered: ladder {:>7.1} tok/s (p99 ttft {:>6.2}s, rejected {:>4}) | \
             no-ladder {:>7.1} tok/s (p99 ttft {:>6.2}s, rejected {:>4}) | ratio {ratio:>5.2}x",
            on.goodput_tps, on.ttft_p99, on.rejected, off.goodput_tps, off.ttft_p99, off.rejected,
        );
        if (x - 2.0).abs() < f64::EPSILON && ratio < 1.5 {
            eprintln!(
                "WARNING: at 2x overload the ladder held only {ratio:.2}x the no-ladder \
                 goodput (acceptance floor is 1.5x)"
            );
        }
    }
}

// ---------------------------------------------------------------------
// Remote expert tier: peer fetch vs local DRAM (artifact-free: a real
// shard server on localhost + the modeled network link class)
// ---------------------------------------------------------------------

/// Modeled peer link: ~1 GB/s with a small RTT, so a cold peer fetch is
/// visibly dearer than a DRAM borrow but the bench stays quick.
const NET_BW: f64 = 1e9;
const NET_LAT: f64 = 100e-6;

/// Two-way shard over the tiny synthetic store: the local node owns the
/// bottom half of the flat expert space, an in-process [`ShardServer`]
/// owns the top half. Times a full sweep of the store through the
/// [`TieredStore`] three ways — local-only (DRAM borrows), cold remote
/// (half the records stream from the peer over the network link class),
/// warm remote (the peer half answered by the staged side-cache) — then
/// prints the remote counters the serving report surfaces, and the
/// N nodes x M users DES sweep (`sim::des::simulate_remote_cluster`).
fn remote_scenario() {
    use hobbit::config::{PeerSpec, RemoteConfig};
    use hobbit::memory::ONDEMAND_WEIGHT;
    use hobbit::remote::{RetryPolicy, ShardServer, ShardSpec, TieredStore};
    use hobbit::sim::des::simulate_remote_cluster;

    let cfg = tiny_store_config("bench-remote");
    let dir = std::env::temp_dir().join("hobbit_bench_remote");
    write_synth_expert_store(&dir, &cfg).expect("synth store");
    let store = Arc::new(ExpertStore::load(&dir, &cfg).expect("store"));
    let half = cfg.total_experts() / 2;
    let peer_shard = ShardSpec::parse(&format!("{half}-{}", cfg.total_experts() - 1)).unwrap();
    let server = ShardServer::bind("127.0.0.1:0", store.clone(), peer_shard.clone(), 16 * 1024)
        .expect("shard server");
    let addr = server.serve_background().to_string();
    let rc = RemoteConfig {
        local_shard: ShardSpec::parse(&format!("0-{}", half - 1)).unwrap(),
        peers: vec![PeerSpec { addr, shard: peer_shard }],
        net_bw: NET_BW,
        net_latency: NET_LAT,
        retry: RetryPolicy::fast(),
        ..RemoteConfig::default()
    };
    let tiered = TieredStore::from_config(store.clone(), &rc, &dir).expect("tiered store");
    let local = TieredStore::local_only(store.clone());

    let keys: Vec<ExpertKey> = (0..cfg.n_layers)
        .flat_map(|l| (0..cfg.n_experts).map(move |e| ExpertKey::new(l, e)))
        .collect();
    let sweep = |ts: &TieredStore| {
        let t0 = Instant::now();
        for &k in &keys {
            let _ = ts.fetch(k, Precision::F32, ONDEMAND_WEIGHT);
        }
        t0.elapsed().as_secs_f64()
    };
    println!(
        "\n== remote expert tier: 2-way shard, {} experts, peer fetch over a modeled \
         {:.1} GB/s link ==\n",
        cfg.total_experts(),
        NET_BW / 1e9,
    );
    let t_local = sweep(&local);
    let t_cold = sweep(&tiered);
    let t_warm = sweep(&tiered);
    println!("local DRAM          full sweep {:>7.2}ms", t_local * 1e3);
    println!("cold  ({half} via peer)  full sweep {:>7.2}ms", t_cold * 1e3);
    println!("warm  (staged)      full sweep {:>7.2}ms", t_warm * 1e3);

    let probe = ExpertKey::new(cfg.n_layers - 1, cfg.n_experts - 1);
    let identical = tiered.fetch(probe, Precision::Q8, ONDEMAND_WEIGHT).as_slice()
        == store.record(probe, Precision::Q8);
    println!("remote record bytes identical to local store: {identical}");
    let c = tiered.counters();
    // the same counters `hobbit serve` emits — "serving" key only
    println!(
        "serving: {{\"remote_fetches\":{},\"remote_bytes\":{},\"remote_retries\":{},\
         \"peer_failovers\":{},\"remote_staged_hits\":{},\"disk_fetches\":{}}}",
        c.remote_fetches, c.remote_bytes, c.remote_retries, c.peer_failovers, c.staged_hits,
        c.disk_fetches,
    );
    if !identical {
        eprintln!("WARNING: peer-served record differed from the local store");
    }
    if t_cold <= t_local {
        eprintln!("WARNING: cold peer fetches were not dearer than DRAM borrows");
    }

    // the DES mirror: M users pinned round-robin across N nodes, each
    // node with its own PCIe link and its own network link (the second
    // link class — peer traffic never shows up as PCIe pressure)
    const DES_USERS: usize = 8;
    const DES_TOKENS: usize = 64;
    println!(
        "\n== DES remote-cluster sweep: {DES_USERS} users x {DES_TOKENS} tokens, \
         1.5 MB experts, PCIe 1.5 GB/s, net {:.0} Gb/s ==\n",
        NET_BW * 8.0 / 1e9,
    );
    for n_nodes in [1usize, 2, 4] {
        let r = simulate_remote_cluster(
            n_nodes,
            DES_USERS,
            DES_TOKENS,
            1_572_864.0,
            0.3,
            0.5,
            2e-3,
            (1.5e9, 30e-6),
            (NET_BW, NET_LAT),
            2,
            7,
        );
        println!(
            "nodes {n_nodes}: {:>7.1} tok/s | remote fetches {:>4}, staged hits {:>4}, \
             net {:>6.1} MB, net util {:.2}",
            r.tps(),
            r.remote_fetches,
            r.staged_hits,
            r.net_bytes / 1e6,
            r.net_utilization(n_nodes),
        );
    }
}

// ---------------------------------------------------------------------
// Ragged grouped decode: one launch per unique expert per layer step
// (artifact-free: reference executor, hot-skewed batch so the routed
// rows pile onto few experts and replication has something to serve)
// ---------------------------------------------------------------------

/// Every row decodes the same prompt greedily, so each batch step routes
/// all rows to the same top-k experts — the worst case for per-row
/// execution (K identical dequants) and the best for grouping.
const HOT_PROMPT: &str = "the mixture of experts model";
const GROUPED_NEW: usize = 10;

/// Reference engine for the grouped A/B: fast link + a cache with free
/// slots beyond the working set (3 layers x 4 experts), so hot-expert
/// replicas have somewhere to live without evicting primaries.
fn grouped_engine(tag: &str, grouped: bool, max_replicas: usize) -> Engine {
    let dir = std::env::temp_dir().join(format!("hobbit_bench_grouped_{tag}"));
    let mut cfg = tiny_model_config("bench-grouped");
    cfg.max_seq = 512;
    write_synth_model(&dir, &cfg, 0x6B07_11E5).expect("synth model");
    let hw = HardwareConfig {
        name: "bench-grouped".into(),
        load_bw: 3e8,
        load_latency: 0.0,
        hi_cache_experts: 16,
        lo_cache_experts: 8,
        cpu_assist: false,
        cpu_expert_time: 0.0,
    };
    let policy = PolicyConfig { prefetch_depth: 2, ..PolicyConfig::default() };
    let mut opts = EngineOptions::new(hw, policy);
    opts.grouped = grouped;
    opts.max_replicas = max_replicas;
    Engine::new_reference(&dir, cfg, opts).expect("reference engine")
}

/// One measured run at a batch width: submit `batch` copies of the hot
/// prompt, drain, return (wall, tokens, report, batch_steps).
fn grouped_run(batch: usize, grouped: bool) -> (f64, usize, RunReport, u64) {
    let tag = format!("{batch}_{}", if grouped { "grouped" } else { "perrow" });
    let eng = grouped_engine(&tag, grouped, if grouped { 2 } else { 0 });
    let mut coord = Coordinator::interleaved(eng);
    coord.max_batch = batch;
    coord.max_active = coord.max_active.max(batch);
    for i in 0..batch {
        coord.submit(Request::new(i as u64 + 1, HOT_PROMPT, GROUPED_NEW));
    }
    let t0 = Instant::now();
    let results = coord.drain().expect("drain");
    let wall = t0.elapsed().as_secs_f64();
    let tokens: usize = results.iter().map(|r| r.tokens.len()).sum();
    coord.sync_report();
    let steps = coord.scheduler_stats().batch_steps;
    (wall, tokens, coord.report.clone(), steps)
}

/// Grouped-vs-per-row A/B at batch {4, 16, 64}: per-step launch and
/// dequant counts from the serving counters, plus the replica traffic
/// the hot skew generates. `group_rows` is exactly what the per-row
/// path would have launched, so the collapse ratio reads off one run.
fn grouped_scenario() {
    let n_layers = tiny_model_config("bench-grouped").n_layers as u64;
    println!(
        "\n== ragged grouped decode: {GROUPED_NEW} tokens/seq, hot-skewed batch \
         (every row routes identically), reference executor ==\n"
    );
    let mut batch16_json: Option<String> = None;
    for batch in [4usize, 16, 64] {
        let (gw, gt, grep, gsteps) = grouped_run(batch, true);
        let (pw, pt, _prep, _psteps) = grouped_run(batch, false);
        let ld = &grep.loader;
        let ffn_steps = (gsteps * n_layers).max(1);
        println!(
            "batch {batch:>2}: grouped {gt:>4} tok {gw:>6.2}s ({:>7.1} tok/s) | \
             per-row {pt:>4} tok {pw:>6.2}s ({:>7.1} tok/s)",
            gt as f64 / gw.max(1e-9),
            pt as f64 / pw.max(1e-9),
        );
        println!(
            "          launches/step {:>5.2} vs routed rows/step {:>5.2} \
             ({} launches for {} rows, {} dequant reuses)",
            ld.grouped_launches as f64 / ffn_steps as f64,
            ld.group_rows as f64 / ffn_steps as f64,
            ld.grouped_launches,
            ld.group_rows,
            ld.dequant_reuses,
        );
        println!(
            "          snapshots: {} copies, {} reuses | replicas: {} created, \
             {} hits, {} evictions",
            ld.snapshot_copies,
            ld.snapshot_reuses,
            grep.cache.replicas_created,
            grep.cache.replica_hits,
            grep.cache.replica_evictions,
        );
        if batch >= 16 {
            if ld.dequant_reuses == 0 {
                eprintln!(
                    "WARNING: batch {batch} grouped run reused no dequants on a \
                     hot-skewed trace"
                );
            }
            if 2 * ld.grouped_launches > ld.group_rows {
                eprintln!(
                    "WARNING: batch {batch} launches did not collapse 2x vs per-row \
                     ({} launches for {} rows)",
                    ld.grouped_launches, ld.group_rows,
                );
            }
            if grep.cache.replica_hits == 0 {
                eprintln!(
                    "WARNING: batch {batch} hot-skewed run served no reads from replicas"
                );
            }
        }
        if batch == 16 {
            // the same counters `hobbit serve` emits — "serving" key only
            batch16_json = grep.to_json().get("serving").map(|s| s.to_string());
        }
    }
    if let Some(serving) = batch16_json {
        println!("\nserving (batch 16, grouped): {serving}");
    }
}

fn main() {
    admission_scenario();
    progressive_floor_scenario();
    open_loop_scenario();
    remote_scenario();
    grouped_scenario();

    if !PathBuf::from("artifacts/mixtral-tiny/manifest.json").exists() {
        eprintln!("\nartifacts not built; skipping the FCFS-vs-interleaved serving bench");
        return;
    }
    println!(
        "\n== serving bench: {} requests x {} tokens, offload-bound ({} GB/s, hi cache {}) ==\n",
        PROMPTS.len(),
        MAX_NEW,
        offload_hw().load_bw / 1e9,
        offload_hw().hi_cache_experts,
    );

    let (fcfs_wall, fcfs_tokens, _) = run(SchedulerMode::Fcfs);
    let fcfs_tps = fcfs_tokens as f64 / fcfs_wall;
    println!(
        "fcfs         {fcfs_tokens:>4} tok in {fcfs_wall:>6.2}s  -> {fcfs_tps:>6.2} tok/s aggregate"
    );

    let (il_wall, il_tokens, rep) = run(SchedulerMode::Interleaved);
    let il_tps = il_tokens as f64 / il_wall;
    println!(
        "interleaved  {il_tokens:>4} tok in {il_wall:>6.2}s  -> {il_tps:>6.2} tok/s aggregate"
    );

    let sch = rep.scheduler.clone().expect("interleaved run reports scheduler stats");
    println!(
        "\nspeedup {:.2}x | overlap ratio {:.2} | stall {:.2}s total, {:.2}s unhidden | mean ttft {:.3}s | mean queue wait {:.3}s",
        il_tps / fcfs_tps,
        sch.overlap_ratio(),
        sch.total_stall.as_secs_f64(),
        sch.unhidden_stall.as_secs_f64(),
        sch.mean_ttft_s(),
        sch.mean_queue_wait_s(),
    );
    println!(
        "cross-sequence load dedup: {} of {} on-demand requests joined an in-flight transfer",
        rep.loader.dedup_hits, rep.loader.dedup_total,
    );
    println!(
        "chunked prefill: {} slices, {:.1}ms stall, chunks 128/16/1 = {}/{}/{}",
        sch.prefill_slices,
        sch.prefill_stall.as_secs_f64() * 1e3,
        sch.prefill_chunks[0],
        sch.prefill_chunks[1],
        sch.prefill_chunks[2],
    );
    println!(
        "transfer pipeline: {} preemptions, {} in-flight promotions, {} no-slot drops, \
         time-to-ready ondemand {:.1}ms / prefetch {:.1}ms",
        rep.loader.preemptions,
        rep.loader.inflight_promotions,
        rep.loader.noslot_drops,
        rep.loader.mean_ondemand_ready_ms(),
        rep.loader.mean_prefetch_ready_ms(),
    );
    // the full serving section (the report's "serving" key), prefill-slice
    // stats included — what `hobbit serve --report` emits
    if let Some(serving) = rep.to_json().get("serving") {
        println!("serving: {}", serving.to_string());
    }
    if il_tps <= fcfs_tps {
        eprintln!("WARNING: interleaved did not beat FCFS on this host/config");
    }
    if sch.overlap_ratio() <= 0.0 {
        eprintln!("WARNING: no load stall was hidden (overlap ratio 0)");
    }
}
