//! Fig 18 bench: cache-policy replay throughput and miss penalties over
//! the calibrated synthetic trace set, every policy, two cache sizes.

use hobbit::cache::Policy;
use hobbit::trace::replay::{replay, ReplayConfig};
use hobbit::trace::{generate, TraceGenConfig};
use hobbit::util::benchkit::{bench, header};

fn main() {
    let traces = generate(&TraceGenConfig::mixtral_like(), 4, 96);
    header();
    for (label, hi, lo) in [("small-cache", 16, 24), ("large-cache", 43, 55)] {
        let cfg = ReplayConfig { hi_capacity: hi, lo_capacity: lo, ..Default::default() };
        let mut penalties = Vec::new();
        for (name, p) in [
            ("random", Policy::Random { seed: 3 }),
            ("lru", Policy::Lru),
            ("lfu", Policy::LfuSeq),
            ("lhu", Policy::Lhu),
            ("fld", Policy::Fld),
            ("multidim", Policy::Multidim { w: [0.65, 0.05, 0.10, 0.20] }),
        ] {
            let p2 = p.clone();
            bench(&format!("replay {label} {name}"), || {
                let _ = replay(&traces, p2.clone(), &cfg);
            });
            penalties.push((name, replay(&traces, p, &cfg).penalty));
        }
        let base = penalties[0].1;
        print!("\n{label} normalized penalties:");
        for (name, pen) in &penalties {
            print!(" {name}={:.3}", pen / base);
        }
        println!("\n");
    }
}
