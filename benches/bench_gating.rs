//! Fig 17(a) bench: the Stacking Computer (one stacked gate launch for p
//! layers) vs the naive sequential loop — both as compiled PJRT
//! executables. The paper's claim: stacked cost is ~flat in p, sequential
//! grows linearly.

use std::path::PathBuf;

use hobbit::config::ModelConfig;
use hobbit::runtime::{lit_f32, Runtime};
use hobbit::util::benchkit::{bench, header};

fn main() {
    let dir = PathBuf::from("artifacts/mixtral-tiny");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts not built; run `make artifacts`");
        return;
    }
    let mut rt = Runtime::open(&dir).unwrap();
    let cfg = ModelConfig::from_manifest(&rt.manifest.model_json()).unwrap();
    let (d, e) = (cfg.d_model, cfg.n_experts as usize);

    header();
    let mut results = Vec::new();
    for p in 1..=4usize {
        let x = lit_f32(&[1, d], &vec![0.1; d]).unwrap();
        let pn = lit_f32(&[p, d], &vec![1.0; p * d]).unwrap();
        let wg = lit_f32(&[p, d, e], &vec![0.02; p * d * e]).unwrap();
        for kind in ["gate", "gate_seq"] {
            let name = format!("{kind}_p{p}_s1");
            rt.ensure(&name).unwrap();
            let r = bench(&format!("{name} (p={p})"), || {
                let _ = rt.execute(&name, &[&x, &pn, &wg]).unwrap();
            });
            results.push((kind, p, r.summary.p50));
        }
    }
    // headline ratio: sequential p=4 vs stacked p=4
    let stacked4 = results.iter().find(|r| r.0 == "gate" && r.1 == 4).unwrap().2;
    let seq4 = results.iter().find(|r| r.0 == "gate_seq" && r.1 == 4).unwrap().2;
    let stacked1 = results.iter().find(|r| r.0 == "gate" && r.1 == 1).unwrap().2;
    println!("\nstacked p=4 vs p=1: {:.2}x (flat is 1.0)", stacked4 / stacked1);
    println!("sequential p=4 vs stacked p=4: {:.2}x", seq4 / stacked4);
}
