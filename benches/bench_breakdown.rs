//! Per-artifact PJRT execute timing (Fig 3a real-path counterpart + the
//! L2/L3 perf-pass probe): attention, stacked gating, expert FFN at every
//! precision and chunk size, LM head, plus the expert transfer itself.
//! harness = false (criterion is not in the offline vendor set).

use std::path::PathBuf;

use hobbit::config::{HardwareConfig, PolicyConfig};
use hobbit::engine::{Engine, EngineOptions, KvState};
use hobbit::memory::{LinkModel, ThrottledCopier};
use hobbit::util::benchkit::{bench, header};

fn main() {
    let artifacts = PathBuf::from("artifacts");
    if !artifacts.join("mixtral-tiny/manifest.json").exists() {
        eprintln!("artifacts not built; run `make artifacts`");
        return;
    }
    let hw = HardwareConfig {
        hi_cache_experts: 64,
        lo_cache_experts: 64,
        load_bw: 64e9,
        load_latency: 0.0,
        ..HardwareConfig::rtx4090_real()
    };
    // A/B: pallas-interpret FFN vs XLA-fused fast FFN, same process
    let mut slow_opts = EngineOptions::new(hw.clone(), PolicyConfig::default());
    slow_opts.use_fast_ffn = false;
    let mut slow_engine = Engine::new(&artifacts, "mixtral-tiny", slow_opts).expect("engine");
    let mut engine =
        Engine::new(&artifacts, "mixtral-tiny", EngineOptions::new(hw, PolicyConfig::default()))
            .expect("engine");

    header();
    {
        let mut kv = slow_engine.new_sequence();
        let prompt: Vec<u32> = (0..16u32).map(|i| 65 + i).collect();
        let _ = slow_engine.prefill(&mut kv, &prompt).unwrap();
        bench("engine decode_step (pallas-interpret FFN)", || {
            if kv.remaining() < 2 {
                kv = slow_engine.new_sequence();
                let _ = slow_engine.prefill(&mut kv, &prompt).unwrap();
            }
            let _ = slow_engine.decode_step(&mut kv, 66).unwrap();
        });
    }
    drop(slow_engine);

    // whole-token decode + prefill chunks through the engine
    let mut kv: KvState = engine.new_sequence();
    let prompt: Vec<u32> = (0..16u32).map(|i| 65 + i).collect();
    let _ = engine.prefill(&mut kv, &prompt).unwrap();
    bench("engine decode_step (token, all layers)", || {
        if kv.remaining() < 2 {
            kv = engine.new_sequence();
            let _ = engine.prefill(&mut kv, &prompt).unwrap();
        }
        let _ = engine.decode_step(&mut kv, 66).unwrap();
    });

    let mut kv2 = engine.new_sequence();
    bench("engine prefill chunk s=16", || {
        if kv2.remaining() < 32 {
            kv2 = engine.new_sequence();
        }
        let _ = engine.prefill(&mut kv2, &prompt).unwrap();
    });

    // direct artifact timings (isolated)
    let names: Vec<String> = vec![
        "attn_s1".into(),
        "gate_p1_s1".into(),
        "gate_p3_s1".into(),
        "expert_f32_s1".into(),
        "expert_fast_f32_s1".into(),
        "expert_fast_q8_s1".into(),
        "expert_q8_s1".into(),
        "expert_q2_s1".into(),
        "head_s1".into(),
        "attn_s16".into(),
        "expert_f32_s16".into(),
        "attn_s128".into(),
        "expert_f32_s128".into(),
    ];
    for name in &names {
        if engine.runtime_mut().expect("PJRT engine").ensure(name).is_err() {
            continue;
        }
        let rt = engine.runtime().expect("PJRT engine");
        let spec = rt.manifest.artifacts.get(name).unwrap().clone();
        let args: Vec<xla::Literal> = spec
            .inputs
            .iter()
            .map(|(shape, dt)| {
                let n: usize = shape.iter().product();
                match dt {
                    hobbit::runtime::DType::F32 => {
                        hobbit::runtime::lit_f32(shape, &vec![0.01f32; n]).unwrap()
                    }
                    hobbit::runtime::DType::U8 => {
                        hobbit::runtime::lit_u8(shape, &vec![1u8; n]).unwrap()
                    }
                    hobbit::runtime::DType::I32 => hobbit::runtime::lit_i32(0),
                }
            })
            .collect();
        bench(&format!("artifact {name}"), || {
            let _ = rt.execute(name, &args).unwrap();
        });
    }

    // the transfer engine at the three modeled link rates
    for (label, bw) in [("pcie-scaled 1.5GB/s", 1.5e9), ("ssd-scaled 0.25GB/s", 0.25e9)] {
        let copier = ThrottledCopier::new(LinkModel { bytes_per_s: bw, latency_s: 30e-6 });
        let src = vec![1u8; engine.cfg.bytes_for(hobbit::Precision::F32)];
        let mut dst = vec![0u8; src.len()];
        bench(&format!("expert f32 transfer @ {label}"), || {
            let _ = copier.transfer(&src, &mut dst);
        });
        let srcq = vec![1u8; engine.cfg.bytes_for(hobbit::Precision::Q8)];
        let mut dstq = vec![0u8; srcq.len()];
        bench(&format!("expert q8  transfer @ {label}"), || {
            let _ = copier.transfer(&srcq, &mut dstq);
        });
    }
}
